//! `rsb` — CLI launcher for the relu-strikes-back stack.
//!
//! Subcommands:
//!   info                         list artifact models + parameter counts
//!   train     --model <id>       train from scratch on synthlang [xla]
//!   finetune  --model <id> --from <ckpt>   relufication finetune [xla]
//!   eval      --model <id> [--ckpt <path>] zero-shot task suite + ppl [xla]
//!   generate  --model <id> --prompt "..."  sample text
//!   serve     --model <id> --addr 127.0.0.1:7077   JSON-lines TCP server
//!   specdec   --target <id> --draft <id>   speculative decoding demo
//!
//! `generate`, `serve` and `specdec` take `--backend host|xla`: `xla`
//! (default when compiled with the `xla` feature) executes the AOT
//! artifacts on PJRT; `host` runs the pure-Rust `hostexec` backend — same
//! engine, no PJRT, and the predictor's neuron mask skips FFN weight rows
//! for real. The host backend reads the model geometry from the artifact
//! manifest and the weights from `--ckpt` (or the shared checkpoint;
//! `--random-init` serves deterministic random weights for demos).
//!
//! `specdec` extras: `--gamma <n>`, `--verify-mask dense|agg[:W]|random[:W]`
//! (`--sparse` is the legacy alias for `agg:32`), `--accept
//! greedy|stochastic`; on the host backend the sparse verify pass gathers
//! only the aggregated window's live FFN rows, so the reported sparse
//! speedup is measured wall-clock next to the Thm 1/2 projections.
//!
//! Common options: --artifacts <dir> (default ./artifacts), --steps, --lr,
//! --seed, --ckpt. `generate` and `serve` take the hot-neuron predictor
//! knobs --policy <dense|reuse[:W[:K]]|topp:B[:W]>, --recall-floor <f>
//! (1.0 = shadow mode) and --probe-every <n>; the host backend also takes
//! --threads <n> (decode worker threads over batch rows, 0 = one per
//! core) and --quant <f32|q8> (q8 = per-neuron int8 FFN weights, ~4x fewer
//! bytes per live neuron; host only). `serve` takes --max-tokens-cap <n>
//! (bound on any request's max_tokens, 0 = the model's max_seq) plus the
//! serving-path knobs (generate accepts them too): --kv-pages <n> with
//! --page-size <p> swaps the dense KV batch for a paged pool,
//! --prefill-chunk <n> feeds prompts in chunks so long prefills don't
//! stall in-flight decodes, and --queue-cap <n> sheds load with a JSON
//! backpressure error once that many requests are waiting. Examples
//! under examples/ drive the full paper reproduction; this binary is the
//! day-to-day launcher.
//!
//! Observability (generate/serve/specdec): `--trace <out.jsonl>` records
//! phase spans (prefill, mask-plan, decode-step, attention, ffn-gather,
//! ffn-matvec, verify, draft-step) and dumps Chrome-trace JSONL on exit
//! (load in chrome://tracing or summarize with tools/trace_summary.py);
//! `--report-layers` prints the per-layer sparsity table (density, recall,
//! step-to-step reuse, aggregated-window density) after a `generate` run;
//! `--log-level <error|warn|info|debug>[,json]` (or env PALLAS_LOG) tunes
//! the stderr log stream. A running server also answers `{"cmd":"metrics"}`
//! / `{"cmd":"metrics_prom"}` (Prometheus text exposition) /
//! `{"cmd":"reset"}` over its own TCP protocol. SLO monitors
//! (`--slo-recall-floor <f>`, `--slo-density-ceil <f>`, `--slo-p99-ms <ms>`)
//! watch rolling windows of live recall, enforced density and sketch p99
//! latency, logging ok -> warn -> breach transitions and counting breaches.
//!
//! Weight tiering (host backend): `--resident-mb <mb>` serves the FFN
//! weights through a hot/cold tier under that byte budget — hot neurons
//! stay resident, cold ones are read on demand from a page-aligned tiered
//! checkpoint (packed on first use at `<artifacts>/<id>/model.tier`, or
//! `--tier-file <path>`). `--tier-prefetch <n>` caps the background
//! prefetcher's promotions per layer per hint (default 64; 0 disables the
//! prefetch thread so every cold neuron is a synchronous counted miss).
//! Cold misses, promotions and resident bytes surface on
//! `{"cmd":"metrics"}` / `{"cmd":"metrics_prom"}`.

use std::sync::Arc;

use rsb::engine::{Engine, EngineConfig, NeuronPolicy, SamplingParams};
use rsb::error::{Error, Result};
use rsb::figures::ensure_data;
use rsb::hostexec::HostBackend;
use rsb::runtime::{artifacts_dir, ExecBackend, Manifest};
use rsb::util::cli::Args;

const FLAGS: &[&str] = &["quiet", "sparse", "help", "random-init", "report-layers"];

fn main() {
    rsb::obs::log::init_from_env();
    let args = Args::from_env(FLAGS);
    if let Some(spec) = args.get("log-level") {
        if let Err(e) = rsb::obs::log::set_spec(spec) {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            rsb::log_error!("rsb", "{e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "info" => info(args),
        "train" => compiled::train(args, None),
        "finetune" => {
            let from = args.require("from")?;
            compiled::train(args, Some(from))
        }
        "eval" => compiled::eval(args),
        "generate" => generate(args),
        "serve" => serve(args),
        "specdec" => specdec(args),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "rsb — ReLU Strikes Back reproduction (see README.md)
usage: rsb <info|train|finetune|eval|generate|serve|specdec> [--options]
       generate/serve/specdec take --backend host|xla (host = no PJRT)
       host backend: --quant f32|q8 (int8 FFN weights), --threads N,
              --resident-mb N (hot/cold FFN weight tier under an N MiB budget;
              packs <artifacts>/<id>/model.tier on first use, --tier-file PATH
              overrides), --tier-prefetch N (promotions per layer per hint,
              0 = no prefetch thread)
       serve: --max-tokens-cap N (0 = model max_seq), --queue-cap N (backpressure),
              --kv-pages N --page-size P (paged KV pool), --prefill-chunk N
       SLO monitors (generate/serve): --slo-recall-floor F --slo-density-ceil F
              --slo-p99-ms MS (rolling-window watchers; breaches are logged and
              counted, see {\"cmd\":\"metrics_prom\"})
       specdec: --gamma N --verify-mask dense|agg[:W]|random[:W] --accept greedy|stochastic";

/// Engine config from the predictor CLI knobs (defaults = dense serving).
fn engine_config(args: &Args) -> Result<EngineConfig> {
    let mut cfg = EngineConfig::default();
    if let Some(spec) = args.get("policy") {
        cfg.policy = NeuronPolicy::parse(spec)?;
    }
    cfg.recall_floor = args.f64_or("recall-floor", cfg.recall_floor)?;
    cfg.probe_every = args.usize_or("probe-every", cfg.probe_every)?;
    // serving-path knobs: paged KV pool, chunked prefill, admission queue cap
    let n_pages = args.usize_or("kv-pages", 0)?;
    let page_size = args.usize_or("page-size", 16)?;
    if n_pages > 0 {
        if page_size == 0 {
            return Err(Error::Config("--page-size must be > 0".into()));
        }
        cfg.paged_kv = Some(rsb::engine::PagedKvCfg { page_size, n_pages });
    }
    cfg.prefill_chunk = args.usize_or("prefill-chunk", cfg.prefill_chunk)?;
    cfg.queue_cap = args.usize_or("queue-cap", cfg.queue_cap)?;
    // SLO monitors: rolling-window watchers over predictor recall, enforced
    // mask density and p99 request latency (unset = unwatched)
    cfg.slo_recall_floor = slo_bound(args, "slo-recall-floor")?;
    cfg.slo_density_ceil = slo_bound(args, "slo-density-ceil")?;
    cfg.slo_p99_ms = slo_bound(args, "slo-p99-ms")?;
    Ok(cfg)
}

/// Parse an optional `--<key> <f64>` SLO bound.
fn slo_bound(args: &Args, key: &str) -> Result<Option<f64>> {
    match args.get(key) {
        None => Ok(None),
        Some(v) => v
            .parse::<f64>()
            .map(Some)
            .map_err(|_| Error::Config(format!("--{key}: expected a number, got `{v}`"))),
    }
}

/// `--trace <path>` plumbing: a shared sink when requested (64k-event ring;
/// older events are overwritten and counted) plus the dump path.
fn trace_sink(args: &Args) -> Option<(Arc<rsb::obs::TraceSink>, String)> {
    args.get("trace")
        .map(|p| (Arc::new(rsb::obs::TraceSink::new(1 << 16)), p.to_string()))
}

/// Write the recorded spans as Chrome-trace JSONL once the run finished.
fn dump_trace(trace: &Option<(Arc<rsb::obs::TraceSink>, String)>) -> Result<()> {
    if let Some((sink, path)) = trace {
        sink.dump_to_path(std::path::Path::new(path))?;
        rsb::log_info!(
            "trace",
            "wrote {} spans to {path} ({} dropped)",
            sink.len(),
            sink.dropped()
        );
    }
    Ok(())
}

fn default_backend() -> &'static str {
    if cfg!(feature = "xla") {
        "xla"
    } else {
        "host"
    }
}

/// `--quant f32|q8`: FFN weight representation (host backends only).
fn parse_quant(args: &Args) -> Result<rsb::hostexec::QuantMode> {
    let spec = args.str_or("quant", "f32");
    rsb::hostexec::QuantMode::parse(&spec).ok_or_else(|| {
        Error::Config(format!(
            "unknown --quant `{spec}` (expected `f32` or `q8`)"
        ))
    })
}

/// Build the serving engine for the selected `--backend`.
fn build_engine(args: &Args) -> Result<Engine> {
    match args.str_or("backend", default_backend()).as_str() {
        "host" => host_engine(args),
        "xla" => {
            if parse_quant(args)? != rsb::hostexec::QuantMode::F32 {
                return Err(Error::Config(
                    "--quant q8 needs --backend host (the compiled entries are f32)".into(),
                ));
            }
            if args.get("resident-mb").is_some() {
                return Err(Error::Config(
                    "--resident-mb needs --backend host (weight tiering lives in the \
                     host gather path)"
                        .into(),
                ));
            }
            compiled::engine(args)
        }
        other => Err(Error::Config(format!(
            "unknown backend `{other}` (expected `host` or `xla`)"
        ))),
    }
}

/// Host path: geometry from the artifact manifest, weights from a
/// checkpoint (no PJRT client, no compiled entries).
fn host_engine(args: &Args) -> Result<Engine> {
    let artifacts = artifacts_dir(args.get("artifacts"));
    let id = args.str_or("model", "base_opt_relu_s0");
    let manifest = Manifest::load(&artifacts.join(&id))?;
    let (decode_b, prefill_t) = (manifest.buckets.decode_b, manifest.buckets.prefill_t);
    let cfg = manifest.config.clone();
    let backend = if args.has("random-init") {
        rsb::log_info!("host", "serving deterministic random weights (--random-init)");
        HostBackend::random(cfg, args.usize_or("seed", 0)? as u64, decode_b, prefill_t)?
    } else {
        let shared = rsb::figures::shared_checkpoint(&id, "latest");
        let path = match args.get("ckpt") {
            Some(p) => std::path::PathBuf::from(p),
            None if shared.exists() => shared,
            None => {
                return Err(Error::Config(format!(
                    "host backend needs weights: pass --ckpt <path> (or \
                     --random-init); no shared checkpoint at {}",
                    shared.display()
                )))
            }
        };
        HostBackend::from_checkpoint(cfg, &path, decode_b, prefill_t)?
    };
    // decode worker threads over batch rows (0 = one per available core)
    let backend = backend
        .with_threads(args.usize_or("threads", 0)?)
        .with_quant(parse_quant(args)?);
    let backend = apply_tiering(args, backend, &artifacts, &id)?;
    rsb::log_info!(
        "host",
        "{} | L{} d{} f{} v{} | decode_b {} prefill_t {} | threads {} | quant {}",
        backend.model_id(),
        manifest.config.n_layers,
        manifest.config.d_model,
        manifest.config.d_ff,
        manifest.config.vocab,
        decode_b,
        prefill_t,
        backend.threads(),
        backend.quant().name()
    );
    Engine::new(Box::new(backend), engine_config(args)?)
}

/// `--resident-mb <mb>` (host only): re-serve the FFN weights through a
/// hot/cold tier under a byte budget. The tiered checkpoint defaults to
/// `<artifacts>/<id>/model.tier` and is packed from the already-loaded
/// weights when missing; `--tier-file <path>` points at an existing one.
/// `--tier-prefetch <n>` caps promotions per layer per hint (0 disables
/// the prefetch thread: every cold neuron is a synchronous counted miss).
fn apply_tiering(
    args: &Args,
    backend: HostBackend,
    artifacts: &std::path::Path,
    id: &str,
) -> Result<HostBackend> {
    let Some(mb) = args.get("resident-mb") else {
        return Ok(backend);
    };
    let mb: u64 = mb
        .parse()
        .map_err(|_| Error::Config(format!("--resident-mb: expected MiB, got `{mb}`")))?;
    let path = match args.get("tier-file") {
        Some(p) => std::path::PathBuf::from(p),
        None => artifacts.join(id).join("model.tier"),
    };
    if !path.exists() {
        rsb::log_info!("tier", "packing tiered checkpoint: {}", path.display());
        backend.params().write_tiered(&path, None)?;
    }
    let prefetch = args.usize_or("tier-prefetch", 64)?;
    let backend = backend.with_tiering(&path, mb, prefetch)?;
    if let Some(st) = backend.tier_stats() {
        rsb::log_info!(
            "tier",
            "budget {mb} MiB -> {} hot neurons ({:.1} MiB resident) over {:.1} MiB cold | \
             prefetch {prefetch}/layer/hint",
            st.hot_neurons,
            st.resident_bytes as f64 / (1024.0 * 1024.0),
            st.cold_bytes as f64 / (1024.0 * 1024.0),
        );
    }
    Ok(backend)
}

fn info(args: &Args) -> Result<()> {
    let artifacts = artifacts_dir(args.get("artifacts"));
    let models = rsb::runtime::artifact::list_models(&artifacts)?;
    println!("artifacts dir: {}", artifacts.display());
    for id in models {
        match Manifest::load(&artifacts.join(&id)) {
            Ok(m) => println!(
                "  {id:<28} {:>8} params  entries: {}",
                rsb::util::eng(m.param_count as f64),
                m.entries.keys().cloned().collect::<Vec<_>>().join(",")
            ),
            Err(e) => println!("  {id:<28} <error: {e}>"),
        }
    }
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let mut engine = build_engine(args)?;
    let trace = trace_sink(args);
    engine.set_trace(trace.as_ref().map(|(s, _)| s.clone()));
    let vocab = engine.backend().config().vocab;
    let (_ds, bpe) = ensure_data(vocab, 2_000_000, 42)?;
    let prompt = args.str_or("prompt", "ada lives in");
    let max_tokens = args.usize_or("max-tokens", 16)?;
    let sampling = SamplingParams {
        temperature: args.f64_or("temperature", 0.0)?,
        top_k: args.usize_or("top-k", 0)?,
        seed: args.usize_or("seed", 0)? as u64,
    };
    engine.submit_with(bpe.encode(&prompt), max_tokens, sampling);
    let done = engine.run_to_completion()?;
    for c in done {
        println!("prompt: {prompt}");
        println!("output: {}", bpe.decode(&c.tokens));
        println!(
            "  {} tokens, prefill {:.1}ms, total {:.1}ms ({:.1} tok/s)",
            c.tokens.len(),
            c.prefill_ms,
            c.total_ms,
            c.tokens_per_sec()
        );
    }
    println!("{}", engine.metrics.report());
    if args.has("report-layers") {
        println!("{}", engine.metrics.per_layer.report());
    }
    dump_trace(&trace)?;
    Ok(())
}

fn serve(args: &Args) -> Result<()> {
    let mut engine = build_engine(args)?;
    let trace = trace_sink(args);
    engine.set_trace(trace.as_ref().map(|(s, _)| s.clone()));
    let vocab = engine.backend().config().vocab;
    let (_ds, bpe) = ensure_data(vocab, 2_000_000, 42)?;
    let addr = args.str_or("addr", "127.0.0.1:7077");
    let max = args.get("max-requests").map(|v| v.parse().unwrap_or(0));
    // per-request max_tokens cap (0 = the model's max_seq)
    let cap = args.usize_or("max-tokens-cap", 0)?;
    rsb::server::serve(engine, Arc::new(bpe), &addr, max, None, cap)?;
    dump_trace(&trace)?;
    Ok(())
}

/// One side of a host speculative-decoding pair: geometry from the artifact
/// manifest (B=1, verify bucket from `buckets.verify_g`), weights from the
/// side's own `--target-ckpt`/`--draft-ckpt` (or the shared checkpoint, or
/// `--random-init`).
fn host_specdec_side(
    args: &Args,
    id_key: &str,
    ckpt_key: &str,
    default_id: &str,
    seed_offset: u64,
) -> Result<rsb::hostexec::HostBackend> {
    let artifacts = artifacts_dir(args.get("artifacts"));
    let id = args.str_or(id_key, default_id);
    let manifest = Manifest::load(&artifacts.join(&id))?;
    let cfg = manifest.config.clone();
    let prefill_t = manifest.buckets.prefill_t;
    let verify_g = manifest.buckets.verify_g;
    let backend = if args.has("random-init") {
        HostBackend::random(
            cfg,
            args.usize_or("seed", 0)? as u64 + seed_offset,
            1,
            prefill_t,
        )?
    } else {
        let shared = rsb::figures::shared_checkpoint(&id, "latest");
        let path = match args.get(ckpt_key) {
            Some(p) => std::path::PathBuf::from(p),
            None if shared.exists() => shared,
            None => {
                return Err(Error::Config(format!(
                    "host specdec needs weights for `{id}`: pass \
                     --{ckpt_key} <path> (or --random-init); no shared \
                     checkpoint at {}",
                    shared.display()
                )))
            }
        };
        HostBackend::from_checkpoint(cfg, &path, 1, prefill_t)?
    };
    let backend = backend.with_quant(parse_quant(args)?);
    backend.with_verify_g(verify_g)
}

/// Speculative decoding on either backend: draft proposes γ tokens, the
/// target verifies them in one (optionally sparse) pass.
fn specdec(args: &Args) -> Result<()> {
    use rsb::costmodel::specdec::verify_comparison;
    use rsb::engine::{AcceptMode, SpecDecoder, VerifyMask};

    let gamma = args.usize_or("gamma", 4)?;
    let mode = AcceptMode::parse(&args.str_or("accept", "greedy"))?;
    let mask = if let Some(spec) = args.get("verify-mask") {
        VerifyMask::parse(spec)?
    } else if args.has("sparse") {
        VerifyMask::Aggregated { window: 32 }
    } else {
        VerifyMask::Dense
    };
    let seed = args.usize_or("seed", 0)? as u64;
    let mut dec = match args.str_or("backend", default_backend()).as_str() {
        "host" => {
            let target = host_specdec_side(args, "target", "target-ckpt", "base_opt_relu_s0", 0)?;
            let draft = host_specdec_side(args, "draft", "draft-ckpt", "draft_opt_relu_s0", 1)?;
            rsb::log_info!(
                "host",
                "specdec target {} | draft {} | gamma {gamma} | {mask:?}",
                target.model_id(),
                draft.model_id()
            );
            SpecDecoder::new(Box::new(target), Box::new(draft), gamma, mode, mask, seed)?
        }
        "xla" => compiled::specdec_decoder(args, gamma, mode, mask, seed)?,
        other => {
            return Err(Error::Config(format!(
                "unknown backend `{other}` (expected `host` or `xla`)"
            )))
        }
    };
    let trace = trace_sink(args);
    dec.set_trace(trace.as_ref().map(|(s, _)| s.clone()));
    let vocab = dec.target().config().vocab;
    let (_ds, bpe) = ensure_data(vocab, 2_000_000, 42)?;
    let prompt = bpe.encode(&args.str_or("prompt", "ada lives in"));
    let n = args.usize_or("max-tokens", 24)?;
    let (tokens, stats) = dec.generate(&prompt, n)?;
    println!("output: {}", bpe.decode(&tokens));
    println!(
        "rounds {} | drafted {} accepted {} (alpha≈{:.2}) | tokens/round {:.2} | \
         c measured {:.3} | s_agg(gamma) {:.2} | verify {:.3}ms/round",
        stats.rounds,
        stats.drafted,
        stats.accepted,
        stats.acceptance_rate(),
        stats.tokens_per_round(),
        stats.c_measured,
        stats.s_agg_gamma,
        stats.verify_secs_per_round() * 1e3,
    );
    if mask != VerifyMask::Dense {
        if dec.target().kind() == "host" {
            // measured-vs-modeled: rerun densely so the sparse verify
            // wall-clock has a baseline (host: both are real gathers)
            let sparse_verify = stats.verify_secs_per_round();
            let mut dense = dec;
            dense.mask_mode = VerifyMask::Dense;
            let (_t, dstats) = dense.generate(&prompt, n)?;
            let cmp = verify_comparison(
                dstats.verify_secs_per_round(),
                sparse_verify,
                stats.c_measured,
                gamma,
                stats.s_agg_gamma,
                stats.acceptance_rate(),
            );
            println!(
                "sparse verify vs dense: measured {:.2}x | Thm1 {:.2}x (agreement {:.2}) | \
                 Thm2 vs autoregressive {:.2}x",
                cmp.measured_speedup, cmp.thm1_speedup, cmp.agreement, cmp.thm2_speedup,
            );
        } else {
            // the compiled verify entry executes densely under the mask
            // (interpret-mode HLO): speedups there are modeled, not timed
            let cmp = verify_comparison(
                0.0,
                0.0,
                stats.c_measured,
                gamma,
                stats.s_agg_gamma,
                stats.acceptance_rate(),
            );
            println!(
                "sparse verify (modeled — compiled path runs the mask densely): \
                 Thm1 {:.2}x | Thm2 vs autoregressive {:.2}x",
                cmp.thm1_speedup, cmp.thm2_speedup,
            );
        }
    }
    dump_trace(&trace)?;
    Ok(())
}

/// Compiled-path subcommands (PJRT). Stubs that explain themselves when the
/// binary was built `--no-default-features`.
#[cfg(feature = "xla")]
mod compiled {
    use super::*;
    use rsb::data::Dataset;
    use rsb::engine::{AcceptMode, SpecDecoder, VerifyMask};
    use rsb::evalx::EvalHarness;
    use rsb::runtime::{cpu_client, Model};
    use rsb::train::{TrainConfig, Trainer};

    pub fn engine(args: &Args) -> Result<Engine> {
        let model = open_model(args, "model")?;
        let params = load_params_arg(&model, args)?;
        Engine::with_model(model, params, engine_config(args)?)
    }

    fn open_model(args: &Args, key: &str) -> Result<Arc<Model>> {
        let artifacts = artifacts_dir(args.get("artifacts"));
        let id = args.str_or(key, "base_opt_relu_s0");
        Ok(Arc::new(Model::open(cpu_client()?, &artifacts, &id)?))
    }

    fn data_for(model: &Model) -> Result<(Dataset, rsb::tokenizer::Bpe)> {
        let vocab = model.manifest.config.vocab;
        ensure_data(vocab, 2_000_000, 42)
    }

    fn load_params_arg(model: &Arc<Model>, args: &Args) -> Result<rsb::runtime::ParamStore> {
        match args.get("ckpt") {
            Some(p) => model.load_params(std::path::Path::new(p)),
            None => {
                let shared =
                    rsb::figures::shared_checkpoint(&model.manifest.model_id, "latest");
                if shared.exists() {
                    model.load_params(&shared)
                } else {
                    rsb::log_warn!("xla", "no checkpoint found; using random init");
                    model.init_params(args.usize_or("seed", 0)? as u32)
                }
            }
        }
    }

    pub fn train(args: &Args, from: Option<String>) -> Result<()> {
        let model = open_model(args, "model")?;
        let (ds, _bpe) = data_for(&model)?;
        let trainer = Trainer::new(model.clone(), Arc::new(ds))?;
        let steps = args.usize_or("steps", 200)?;
        let mut cfg = TrainConfig::quick(steps, args.f64_or("lr", 1e-3)?);
        cfg.seed = args.usize_or("seed", 0)? as u64;
        cfg.eval_every = args.usize_or("eval-every", steps.max(1) / 4)?;
        cfg.quiet = args.has("quiet");
        let ckpt = args.str_or(
            "ckpt",
            rsb::figures::shared_checkpoint(&model.manifest.model_id, "latest")
                .to_str()
                .unwrap(),
        );
        cfg.checkpoint = Some(ckpt.into());
        let outcome = match from {
            None => trainer.train(&cfg)?,
            Some(path) => {
                let params = model.load_params(std::path::Path::new(&path))?;
                trainer.train_from(params, &cfg)?
            }
        };
        println!(
            "done: final loss {:.4} after {} steps ({:.1}s, {} tokens)",
            outcome.final_train_loss,
            steps,
            outcome.wall_secs,
            rsb::util::eng(outcome.tokens_seen as f64)
        );
        Ok(())
    }

    pub fn eval(args: &Args) -> Result<()> {
        let model = open_model(args, "model")?;
        let (ds, bpe) = data_for(&model)?;
        let params = load_params_arg(&model, args)?;
        let harness = EvalHarness::new(model.clone(), Arc::new(bpe));
        let world = rsb::data::World::new(42);
        let n = args.usize_or("items", 40)?;
        let k_shot = args.usize_or("shots", 0)?;
        let mut rows = Vec::new();
        for kind in rsb::data::ALL_TASKS {
            let r = harness.run_task(&params, &world, kind, n, k_shot, 7)?;
            rows.push(vec![
                r.kind.to_string(),
                format!("{:.1}%", r.accuracy() * 100.0),
                format!("{:.1}%", r.ffn_sparsity * 100.0),
                format!("{:.1}%", r.qkv_sparsity * 100.0),
            ]);
        }
        let doc = ds.val_document(0, 2000);
        let ppl = harness.perplexity(&params, &doc)?;
        println!(
            "{}",
            rsb::util::render_table(&["task", "acc", "ffn-sparsity", "qkv-sparsity"], &rows)
        );
        println!("val perplexity: {ppl:.3}");
        Ok(())
    }

    pub fn specdec_decoder(
        args: &Args,
        gamma: usize,
        mode: AcceptMode,
        mask: VerifyMask,
        seed: u64,
    ) -> Result<SpecDecoder> {
        let artifacts = artifacts_dir(args.get("artifacts"));
        let client = cpu_client()?;
        let target = Arc::new(Model::open(
            client.clone(),
            &artifacts,
            &args.str_or("target", "base_opt_relu_s0"),
        )?);
        let draft = Arc::new(Model::open(
            client,
            &artifacts,
            &args.str_or("draft", "draft_opt_relu_s0"),
        )?);
        let tp = load_params_named(&target, args, "target-ckpt")?;
        let dp = load_params_named(&draft, args, "draft-ckpt")?;
        SpecDecoder::with_models(target, tp, draft, dp, gamma, mode, mask, seed)
    }

    fn load_params_named(
        model: &Arc<Model>,
        args: &Args,
        key: &str,
    ) -> Result<rsb::runtime::ParamStore> {
        match args.get(key) {
            Some(p) => model.load_params(std::path::Path::new(p)),
            None => {
                let shared =
                    rsb::figures::shared_checkpoint(&model.manifest.model_id, "latest");
                if shared.exists() {
                    model.load_params(&shared)
                } else {
                    model.init_params(0)
                }
            }
        }
    }
}

/// Host-only build: the compiled-path subcommands explain what's missing
/// instead of failing to link.
#[cfg(not(feature = "xla"))]
mod compiled {
    use super::*;

    fn unavailable(what: &str) -> Error {
        Error::Config(format!(
            "`{what}` needs the compiled XLA path; this binary was built \
             --no-default-features. Rebuild with the `xla` feature, or use \
             --backend host for generate/serve."
        ))
    }

    pub fn engine(_args: &Args) -> Result<Engine> {
        Err(unavailable("--backend xla"))
    }

    pub fn train(_args: &Args, _from: Option<String>) -> Result<()> {
        Err(unavailable("train/finetune"))
    }

    pub fn eval(_args: &Args) -> Result<()> {
        Err(unavailable("eval"))
    }

    pub fn specdec_decoder(
        _args: &Args,
        _gamma: usize,
        _mode: rsb::engine::AcceptMode,
        _mask: rsb::engine::VerifyMask,
        _seed: u64,
    ) -> Result<rsb::engine::SpecDecoder> {
        Err(unavailable("specdec --backend xla"))
    }
}
