//! Data substrates: the synthetic corpus (grammar), token dataset/batching,
//! and the zero/few-shot evaluation task generators.

pub mod dataset;
pub mod grammar;
pub mod tasks;

pub use dataset::Dataset;
pub use grammar::{Generator, World};
pub use tasks::{Item, TaskKind, ALL_TASKS};
