//! Token dataset: corpus text -> BPE tokens -> train/val windows + batches.

use std::path::Path;

use crate::data::grammar::Generator;
use crate::error::Result;
use crate::runtime::tensor::Tensor;
use crate::tokenizer::Bpe;
use crate::util::rng::Rng;

pub struct Dataset {
    pub train: Vec<u32>,
    pub val: Vec<u32>,
    pub vocab_size: usize,
}

impl Dataset {
    /// Build the synthlang dataset: generate text, train (or load) the BPE
    /// tokenizer, encode, split 95/5.
    pub fn synthetic(seed: u64, target_chars: usize, vocab_size: usize) -> Result<(Dataset, Bpe)> {
        let mut gen = Generator::new(seed);
        let text = gen.corpus(target_chars);
        let bpe = Bpe::train(&text[..text.len().min(200_000)], vocab_size)?;
        let tokens = bpe.encode(&text);
        Ok((Self::from_tokens(tokens, bpe.vocab_size()), bpe))
    }

    /// Same corpus with a pre-trained tokenizer (so model vocab stays fixed
    /// across experiments).
    pub fn synthetic_with(bpe: &Bpe, seed: u64, target_chars: usize) -> Dataset {
        let mut gen = Generator::new(seed);
        let text = gen.corpus(target_chars);
        Self::from_tokens(bpe.encode(&text), bpe.vocab_size())
    }

    pub fn from_tokens(tokens: Vec<u32>, vocab_size: usize) -> Dataset {
        let split = tokens.len() * 95 / 100;
        let (train, val) = tokens.split_at(split);
        Dataset {
            train: train.to_vec(),
            val: val.to_vec(),
            vocab_size,
        }
    }

    /// Sample a [K, B, T+1] i32 batch tensor of random training windows.
    pub fn train_batch(&self, rng: &mut Rng, k: usize, b: usize, t: usize) -> Result<Tensor> {
        self.windows(&self.train, rng, k * b, t + 1)
            .map(|flat| Tensor::i32(vec![k, b, t + 1], flat).expect("shape"))
    }

    /// Sample a [B, T+1] i32 batch from the validation split.
    pub fn val_batch(&self, rng: &mut Rng, b: usize, t: usize) -> Result<Tensor> {
        self.windows(&self.val, rng, b, t + 1)
            .map(|flat| Tensor::i32(vec![b, t + 1], flat).expect("shape"))
    }

    /// A deterministic contiguous stretch of validation tokens (perplexity
    /// and reuse experiments want a fixed document).
    pub fn val_document(&self, offset: usize, len: usize) -> Vec<u32> {
        let src = &self.val;
        (0..len).map(|i| src[(offset + i) % src.len()]).collect()
    }

    fn windows(&self, src: &[u32], rng: &mut Rng, n: usize, width: usize) -> Result<Vec<i32>> {
        if src.len() < width + 1 {
            return Err(crate::error::Error::msg(format!(
                "dataset too small: {} tokens < window {width}",
                src.len()
            )));
        }
        let mut out = Vec::with_capacity(n * width);
        for _ in 0..n {
            let start = rng.below(src.len() - width);
            out.extend(src[start..start + width].iter().map(|&t| t as i32));
        }
        Ok(out)
    }

    pub fn save_tokens(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut bytes = Vec::with_capacity((self.train.len() + self.val.len()) * 4 + 12);
        bytes.extend_from_slice(&(self.vocab_size as u32).to_le_bytes());
        bytes.extend_from_slice(&(self.train.len() as u32).to_le_bytes());
        bytes.extend_from_slice(&(self.val.len() as u32).to_le_bytes());
        for t in self.train.iter().chain(self.val.iter()) {
            bytes.extend_from_slice(&t.to_le_bytes());
        }
        std::fs::write(path, bytes)?;
        Ok(())
    }

    pub fn load_tokens(path: &Path) -> Result<Dataset> {
        let bytes = std::fs::read(path)?;
        let rd = |i: usize| u32::from_le_bytes(bytes[i * 4..i * 4 + 4].try_into().unwrap());
        let vocab_size = rd(0) as usize;
        let nt = rd(1) as usize;
        let nv = rd(2) as usize;
        let mut train = Vec::with_capacity(nt);
        let mut val = Vec::with_capacity(nv);
        for i in 0..nt {
            train.push(rd(3 + i));
        }
        for i in 0..nv {
            val.push(rd(3 + nt + i));
        }
        Ok(Dataset {
            train,
            val,
            vocab_size,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_builds_and_batches() {
        let (ds, bpe) = Dataset::synthetic(1, 30_000, 256).unwrap();
        assert!(ds.train.len() > 1000);
        assert!(ds.val.len() > 50);
        assert_eq!(ds.vocab_size, bpe.vocab_size());
        let mut rng = Rng::new(0);
        let b = ds.train_batch(&mut rng, 2, 3, 16).unwrap();
        assert_eq!(b.shape, vec![2, 3, 17]);
        let toks = b.as_i32().unwrap();
        assert!(toks.iter().all(|&t| t >= 0 && (t as usize) < ds.vocab_size));
    }

    #[test]
    fn val_document_wraps() {
        let ds = Dataset::from_tokens((0..100u32).collect(), 128);
        let doc = ds.val_document(ds.val.len() - 2, 5);
        assert_eq!(doc.len(), 5);
        assert_eq!(doc[2], ds.val[0]);
    }

    #[test]
    fn token_file_roundtrip() {
        let ds = Dataset::from_tokens((0..1000u32).map(|x| x % 97).collect(), 97);
        let dir = std::env::temp_dir().join(format!("rsb_ds_{}", std::process::id()));
        let p = dir.join("tokens.bin");
        ds.save_tokens(&p).unwrap();
        let ds2 = Dataset::load_tokens(&p).unwrap();
        assert_eq!(ds.train, ds2.train);
        assert_eq!(ds.val, ds2.val);
        assert_eq!(ds.vocab_size, ds2.vocab_size);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn batches_are_seed_deterministic() {
        let (ds, _) = Dataset::synthetic(2, 20_000, 256).unwrap();
        let a = ds.train_batch(&mut Rng::new(9), 1, 2, 8).unwrap();
        let b = ds.train_batch(&mut Rng::new(9), 1, 2, 8).unwrap();
        assert_eq!(a, b);
    }
}
