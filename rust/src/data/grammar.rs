//! "synthlang": a synthetic PCFG corpus with long-range structure.
//!
//! Stands in for RefinedWeb (DESIGN.md §3): rich enough that (a) language-
//! model loss separates good from bad models, (b) zero-shot tasks (facts,
//! agreement, copy patterns) are learnable, and (c) FFN neurons specialize,
//! making aggregated sparsity (§5.1) non-trivial.
//!
//! Structure:
//!   - entity facts fixed per corpus seed: name -> city / food / animal /
//!     color (support cloze tasks, exercised repeatedly in the corpus);
//!   - SVO sentences with number agreement (singular/plural verb forms) and
//!     animacy class selection (multichoice grammaticality tasks);
//!   - copy/induction segments ("echo : a b c ; a b c .") probing in-context
//!     reuse (the induction behaviour speculative drafting exploits).

use crate::util::rng::Rng;

pub const NAMES: &[&str] = &[
    "ada", "bo", "cyr", "dee", "eli", "fay", "gus", "hal", "ivy", "jo",
    "kai", "lou", "max", "nia", "oz", "pam",
];
pub const CITIES: &[&str] = &[
    "paris", "lima", "oslo", "cairo", "quito", "hanoi", "kyoto", "dakar",
];
pub const FOODS: &[&str] = &[
    "mango", "rice", "soup", "bread", "plum", "corn", "figs", "kale",
];
pub const ANIMALS_SG: &[&str] = &["fox", "bird", "cat", "dog", "hen", "owl"];
pub const ANIMALS_PL: &[&str] = &["foxes", "birds", "cats", "dogs", "hens", "owls"];
pub const COLORS: &[&str] = &["red", "blue", "green", "gray", "gold", "pink"];
pub const VERBS_SG: &[&str] = &["chases", "sees", "likes", "follows", "greets"];
pub const VERBS_PL: &[&str] = &["chase", "see", "like", "follow", "greet"];
pub const ADJS: &[&str] = &["small", "big", "old", "young", "quick", "calm"];
pub const COPY_WORDS: &[&str] = &[
    "alpha", "beta", "gamma", "delta", "kappa", "sigma", "omega", "zeta",
];

/// The fixed world facts of a corpus instance.
#[derive(Debug, Clone)]
pub struct World {
    pub seed: u64,
    pub city_of: Vec<usize>,
    pub food_of: Vec<usize>,
    pub animal_of: Vec<usize>,
    pub color_of: Vec<usize>,
}

impl World {
    pub fn new(seed: u64) -> World {
        let mut r = Rng::new(seed ^ 0xFAC7);
        let assign = |r: &mut Rng, n: usize| -> Vec<usize> {
            (0..NAMES.len()).map(|_| r.below(n)).collect()
        };
        World {
            seed,
            city_of: assign(&mut r, CITIES.len()),
            food_of: assign(&mut r, FOODS.len()),
            animal_of: assign(&mut r, ANIMALS_SG.len()),
            color_of: assign(&mut r, COLORS.len()),
        }
    }
}

/// Sentence kinds with their sampling weights.
const KIND_WEIGHTS: [f64; 6] = [3.0, 2.0, 2.0, 4.0, 2.0, 1.5];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    FactCity,
    FactFood,
    FactPet,
    Svo,
    SvoPlural,
    Copy,
}

pub struct Generator {
    pub world: World,
    rng: Rng,
}

impl Generator {
    pub fn new(seed: u64) -> Generator {
        Generator {
            world: World::new(seed),
            rng: Rng::new(seed),
        }
    }

    pub fn sentence(&mut self) -> String {
        let k = self.rng.categorical(&KIND_WEIGHTS);
        let kind = [
            Kind::FactCity,
            Kind::FactFood,
            Kind::FactPet,
            Kind::Svo,
            Kind::SvoPlural,
            Kind::Copy,
        ][k];
        self.sentence_of(kind)
    }

    pub fn sentence_of(&mut self, kind: Kind) -> String {
        let w = &self.world;
        let r = &mut self.rng;
        match kind {
            Kind::FactCity => {
                let n = r.below(NAMES.len());
                format!("{} lives in {} .", NAMES[n], CITIES[w.city_of[n]])
            }
            Kind::FactFood => {
                let n = r.below(NAMES.len());
                format!("{} eats {} every day .", NAMES[n], FOODS[w.food_of[n]])
            }
            Kind::FactPet => {
                let n = r.below(NAMES.len());
                format!(
                    "{} has a {} {} .",
                    NAMES[n],
                    COLORS[w.color_of[n]],
                    ANIMALS_SG[w.animal_of[n]]
                )
            }
            Kind::Svo => {
                let a = r.below(ANIMALS_SG.len());
                let b = r.below(ANIMALS_SG.len());
                let v = r.below(VERBS_SG.len());
                let adj = *r.choose(ADJS);
                format!(
                    "the {} {} {} the {} .",
                    adj, ANIMALS_SG[a], VERBS_SG[v], ANIMALS_SG[b]
                )
            }
            Kind::SvoPlural => {
                let a = r.below(ANIMALS_PL.len());
                let b = r.below(ANIMALS_SG.len());
                let v = r.below(VERBS_PL.len());
                format!("the {} {} the {} .", ANIMALS_PL[a], VERBS_PL[v], ANIMALS_SG[b])
            }
            Kind::Copy => {
                let len = r.range(2, 5);
                let words: Vec<&str> = (0..len).map(|_| *r.choose(COPY_WORDS)).collect();
                format!("echo : {} ; {} .", words.join(" "), words.join(" "))
            }
        }
    }

    /// Generate ~`target_chars` of corpus text.
    pub fn corpus(&mut self, target_chars: usize) -> String {
        let mut out = String::with_capacity(target_chars + 64);
        while out.len() < target_chars {
            out.push_str(&self.sentence());
            out.push(' ');
        }
        out.pop();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_is_deterministic() {
        let a = World::new(5);
        let b = World::new(5);
        assert_eq!(a.city_of, b.city_of);
        assert_ne!(World::new(6).city_of, a.city_of);
    }

    #[test]
    fn facts_are_consistent_across_corpus() {
        let mut g = Generator::new(3);
        let city = CITIES[g.world.city_of[0]];
        for _ in 0..200 {
            let s = g.sentence_of(Kind::FactCity);
            if s.starts_with("ada lives in") {
                assert!(s.contains(city), "{s}");
            }
        }
    }

    #[test]
    fn copy_sentences_repeat() {
        let mut g = Generator::new(4);
        let s = g.sentence_of(Kind::Copy);
        let parts: Vec<&str> = s
            .trim_start_matches("echo : ")
            .trim_end_matches(" .")
            .split(" ; ")
            .collect();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], parts[1]);
    }

    #[test]
    fn corpus_reaches_target() {
        let mut g = Generator::new(7);
        let c = g.corpus(5000);
        assert!(c.len() >= 5000);
        assert!(c.contains(" . "));
    }

    #[test]
    fn plural_agreement_forms() {
        let mut g = Generator::new(8);
        for _ in 0..50 {
            let s = g.sentence_of(Kind::SvoPlural);
            // plural subject must take plural verb form (no trailing -s forms)
            assert!(
                VERBS_PL.iter().any(|v| s.contains(&format!(" {v} "))),
                "{s}"
            );
        }
    }
}
