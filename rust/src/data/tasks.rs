//! Zero/few-shot evaluation tasks over synthlang (DESIGN.md §3: same
//! logprob-scoring protocol as the paper's LM-Eval-Harness tasks).
//!
//! Task kinds:
//!   - `ClozeCity` / `ClozeFood`: "ada lives in ___" — candidates = all
//!     cities/foods, answer from the corpus world (Table 1's knowledge-probe
//!     analogue, e.g. TriviaQA/LAMBADA).
//!   - `Agreement`: pick the grammatical continuation among corrupted verb
//!     forms (HellaSwag/PIQA analogue).
//!   - `Copy`: induction pattern completion (reading-comprehension analogue).
//!
//! Few-shot (Table 2 / MMLU analogue): k solved examples are prepended to
//! the prompt.

use crate::data::grammar::{
    World, ANIMALS_PL, ANIMALS_SG, CITIES, FOODS, NAMES, VERBS_PL, VERBS_SG,
};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskKind {
    ClozeCity,
    ClozeFood,
    Agreement,
    Copy,
}

pub const ALL_TASKS: [TaskKind; 4] = [
    TaskKind::ClozeCity,
    TaskKind::ClozeFood,
    TaskKind::Agreement,
    TaskKind::Copy,
];

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::ClozeCity => "cloze-city",
            TaskKind::ClozeFood => "cloze-food",
            TaskKind::Agreement => "agreement",
            TaskKind::Copy => "copy",
        }
    }
}

/// One multiple-choice item: shared prompt, candidate continuations, index
/// of the correct candidate.
#[derive(Debug, Clone)]
pub struct Item {
    pub kind: TaskKind,
    pub prompt: String,
    pub candidates: Vec<String>,
    pub answer: usize,
}

/// Generate `n` items of the given kind (deterministic in `seed`, disjoint
/// from training randomness by construction: the *facts* are shared — that
/// is the point — but the sampled combinations differ).
pub fn generate(world: &World, kind: TaskKind, n: usize, k_shot: usize, seed: u64) -> Vec<Item> {
    let mut r = Rng::new(seed ^ 0x7A5C5);
    (0..n).map(|_| item(world, kind, k_shot, &mut r)).collect()
}

fn shot_prefix(world: &World, kind: TaskKind, k: usize, r: &mut Rng) -> String {
    let mut out = String::new();
    for _ in 0..k {
        let it = item(world, kind, 0, r);
        out.push_str(&it.prompt);
        out.push_str(&it.candidates[it.answer]);
        out.push(' ');
    }
    out
}

fn item(world: &World, kind: TaskKind, k_shot: usize, r: &mut Rng) -> Item {
    let prefix = if k_shot > 0 {
        shot_prefix(world, kind, k_shot, r)
    } else {
        String::new()
    };
    match kind {
        TaskKind::ClozeCity => {
            let n = r.below(NAMES.len());
            Item {
                kind,
                prompt: format!("{prefix}{} lives in", NAMES[n]),
                candidates: CITIES.iter().map(|c| format!(" {c} .")).collect(),
                answer: world.city_of[n],
            }
        }
        TaskKind::ClozeFood => {
            let n = r.below(NAMES.len());
            Item {
                kind,
                prompt: format!("{prefix}{} eats", NAMES[n]),
                candidates: FOODS.iter().map(|f| format!(" {f} every day .")).collect(),
                answer: world.food_of[n],
            }
        }
        TaskKind::Agreement => {
            // plural subject: exactly one plural verb among singular lures
            let subj = *r.choose(ANIMALS_PL);
            let obj = *r.choose(ANIMALS_SG);
            let vi = r.below(VERBS_PL.len());
            let mut candidates = vec![format!(" {} the {obj} .", VERBS_PL[vi])];
            let mut lures: Vec<usize> = (0..VERBS_SG.len()).collect();
            r.shuffle(&mut lures);
            for &li in lures.iter().take(3) {
                candidates.push(format!(" {} the {obj} .", VERBS_SG[li]));
            }
            // shuffle candidate order, track the answer
            let mut order: Vec<usize> = (0..candidates.len()).collect();
            r.shuffle(&mut order);
            let answer = order.iter().position(|&i| i == 0).unwrap();
            let shuffled: Vec<String> = order.iter().map(|&i| candidates[i].clone()).collect();
            Item {
                kind,
                prompt: format!("{prefix}the {subj}"),
                candidates: shuffled,
                answer,
            }
        }
        TaskKind::Copy => {
            use crate::data::grammar::COPY_WORDS;
            let len = r.range(2, 4);
            let words: Vec<&str> = (0..len).map(|_| *r.choose(COPY_WORDS)).collect();
            let head = words[..len - 1].join(" ");
            let target = words[len - 1];
            let mut candidates = vec![format!(" {target} .")];
            let mut lures: Vec<&&str> = COPY_WORDS.iter().filter(|w| **w != target).collect();
            r.shuffle(&mut lures);
            for w in lures.iter().take(3) {
                candidates.push(format!(" {} .", **w));
            }
            let mut order: Vec<usize> = (0..candidates.len()).collect();
            r.shuffle(&mut order);
            let answer = order.iter().position(|&i| i == 0).unwrap();
            let shuffled: Vec<String> = order.iter().map(|&i| candidates[i].clone()).collect();
            Item {
                kind,
                prompt: format!("{prefix}echo : {} ; {head}", words.join(" ")),
                candidates: shuffled,
                answer,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn items_have_valid_answers() {
        let w = World::new(1);
        for kind in ALL_TASKS {
            let items = generate(&w, kind, 20, 0, 3);
            assert_eq!(items.len(), 20);
            for it in &items {
                assert!(it.answer < it.candidates.len(), "{kind:?}");
                assert!(!it.prompt.is_empty());
                assert!(it.candidates.iter().all(|c| c.starts_with(' ')));
            }
        }
    }

    #[test]
    fn cloze_answer_matches_world() {
        let w = World::new(2);
        for it in generate(&w, TaskKind::ClozeCity, 30, 0, 4) {
            let name = it.prompt.split(' ').next().unwrap();
            let ni = NAMES.iter().position(|n| *n == name).unwrap();
            assert!(it.candidates[it.answer].contains(CITIES[w.city_of[ni]]));
        }
    }

    #[test]
    fn agreement_answer_is_plural_form() {
        let w = World::new(3);
        for it in generate(&w, TaskKind::Agreement, 30, 0, 5) {
            let ans = &it.candidates[it.answer];
            assert!(
                VERBS_PL.iter().any(|v| ans.starts_with(&format!(" {v} "))),
                "{ans}"
            );
        }
    }

    #[test]
    fn copy_answer_matches_pattern() {
        let w = World::new(4);
        for it in generate(&w, TaskKind::Copy, 30, 0, 6) {
            // "echo : a b ; a" -> answer must be " b ."
            let body = it.prompt.split(" : ").nth(1).unwrap();
            let full: Vec<&str> = body.split(" ; ").next().unwrap().split(' ').collect();
            let want = format!(" {} .", full.last().unwrap());
            assert_eq!(it.candidates[it.answer], want);
        }
    }

    #[test]
    fn few_shot_prefix_grows_prompt() {
        let w = World::new(5);
        let zero = generate(&w, TaskKind::ClozeCity, 5, 0, 7);
        let five = generate(&w, TaskKind::ClozeCity, 5, 5, 7);
        assert!(five[0].prompt.len() > zero[0].prompt.len() * 3);
    }

    #[test]
    fn deterministic_in_seed() {
        let w = World::new(6);
        let a = generate(&w, TaskKind::Copy, 10, 0, 8);
        let b = generate(&w, TaskKind::Copy, 10, 0, 8);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }
}
