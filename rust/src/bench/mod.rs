//! Hand-rolled benchmark harness (criterion is not in the offline crate
//! set). Provides warmup + timed iterations, percentile reporting, aligned
//! console tables and CSV export; `benches/*.rs` use `harness = false`.

use std::io::Write as _;
use std::path::Path;
use std::time::Instant;

use crate::util::stats::Samples;
use crate::util::{eng, render_table};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub samples: Samples,
    /// optional throughput denominator (items per iteration)
    pub items_per_iter: f64,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        self.samples.mean()
    }

    pub fn row(&self) -> Vec<String> {
        let mean = self.samples.mean();
        vec![
            self.name.clone(),
            format!("{}", self.iters),
            format!("{:.3}ms", mean * 1e3),
            format!("{:.3}ms", self.samples.percentile(50.0)* 1e3),
            format!("{:.3}ms", self.samples.percentile(95.0) * 1e3),
            format!("{:.3}ms", self.samples.min() * 1e3),
            if self.items_per_iter > 0.0 {
                format!("{}/s", eng(self.items_per_iter / mean))
            } else {
                "-".into()
            },
        ]
    }
}

pub struct Harness {
    pub suite: String,
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<BenchResult>,
}

impl Harness {
    /// Defaults can be overridden with env RSB_BENCH_ITERS / RSB_BENCH_WARMUP
    /// (the Makefile bench target uses smaller values on CI).
    pub fn new(suite: &str) -> Harness {
        let iters = std::env::var("RSB_BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(20);
        let warmup = std::env::var("RSB_BENCH_WARMUP")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(3);
        Harness {
            suite: suite.to_string(),
            warmup,
            iters,
            results: Vec::new(),
        }
    }

    /// Time `f` (one call = one iteration).
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        self.bench_items(name, 0.0, move |_| f())
    }

    /// Time `f` with a throughput denominator (e.g. tokens per iteration).
    pub fn bench_items(
        &mut self,
        name: &str,
        items_per_iter: f64,
        mut f: impl FnMut(usize),
    ) -> &BenchResult {
        for i in 0..self.warmup {
            f(i);
        }
        let mut samples = Samples::default();
        for i in 0..self.iters {
            let t0 = Instant::now();
            f(i);
            samples.push(t0.elapsed().as_secs_f64());
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: self.iters,
            samples,
            items_per_iter,
        });
        self.results.last().unwrap()
    }

    /// Print the suite table to stdout.
    pub fn report(&self) {
        println!("\n== bench suite: {} ==", self.suite);
        let rows: Vec<Vec<String>> = self.results.iter().map(|r| r.row()).collect();
        print!(
            "{}",
            render_table(
                &["name", "iters", "mean", "p50", "p95", "min", "throughput"],
                &rows
            )
        );
    }

    /// Write CSV (one row per bench) under `dir/<suite>.csv`.
    pub fn write_csv(&self, dir: &Path) -> crate::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{}.csv", self.suite)))?;
        writeln!(f, "name,iters,mean_s,p50_s,p95_s,min_s,items_per_iter")?;
        for r in &self.results {
            writeln!(
                f,
                "{},{},{},{},{},{},{}",
                r.name,
                r.iters,
                r.samples.mean(),
                r.samples.percentile(50.0),
                r.samples.percentile(95.0),
                r.samples.min(),
                r.items_per_iter
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_iterations() {
        std::env::remove_var("RSB_BENCH_ITERS");
        let mut h = Harness::new("t");
        let mut count = 0;
        h.bench("noop", || count += 1);
        assert_eq!(h.results.len(), 1);
        assert_eq!(count, h.warmup + h.iters);
        assert!(h.results[0].samples.len() == h.iters);
    }

    #[test]
    fn throughput_row() {
        let mut h = Harness::new("t2");
        h.bench_items("sleepless", 100.0, |_| {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        let row = h.results[0].row();
        assert!(row[6].ends_with("/s"));
    }
}
