//! TCP JSON-lines inference server + client (std::net; no tokio in the
//! offline crate set, so the accept loop runs on a thread and the engine is
//! driven by a dedicated scheduler thread — Python is never involved).
//!
//! Protocol: one JSON object per line.
//!   request:  {"id": 1, "prompt": "ada lives in", "max_tokens": 8,
//!              "temperature": 0.0, "policy": "reuse:8:4"}
//!   response: {"id": 1, "text": " paris .", "tokens": 3,
//!              "prefill_ms": 12.1, "queue_ms": 0.4, "total_ms": 80.5,
//!              "mask_density": 0.14, "enforced_rows": 6, "fallbacks": 0,
//!              "finish": "max_tokens"}
//!             (`mask_density`/`enforced_rows`/`fallbacks` are *this
//!             request's* sparsity — per-slot masks make them per-request;
//!             `mask_density` is null when no row ever ran sparse)
//!   error:    {"id": 1, "error": "missing key `prompt`"}  (malformed
//!             requests get a JSON error line back, echoing the request id
//!             when one could be parsed)
//!
//! Admin commands (any line carrying a `cmd` key):
//!   {"cmd": "metrics"} -> one JSON snapshot line:
//!              {"engine": <EngineMetrics::to_json(): counters, latency
//!               summaries, per-slot and per-layer series>,
//!               "server": {"served", "queue_depth", "active", "evictions",
//!               "connections": [{"conn", "requests"}, ...]}}
//!   {"cmd": "reset"}   -> {"ok": true, "cmd": "reset"}; zeroes the engine
//!              metrics (keeping slot/layer geometry) and the
//!              per-connection request counters
//!   anything else      -> {"error": "unknown cmd `...`"}
//!
//! `policy` selects the per-request FFN neuron-mask policy
//! (`NeuronPolicy::parse` forms: "dense", "reuse[:W[:K]]", "topp:B[:W]");
//! omitted = the engine's default.
//!
//! Connection lifecycle: the writer thread holds one registered stream per
//! accepted connection and *evicts* it on the first failed write/flush (the
//! peer hung up), so long-lived servers do not accumulate dead sockets;
//! evictions are counted in the `metrics` snapshot. Disconnects propagate
//! to the scheduler (reader EOF and writer evictions both report the dead
//! `conn_id`), which reclaims the connection's scheduler state — queued
//! completions that can no longer be delivered (`pending`) and its
//! request counter (`req_counts`) — so those maps cannot grow
//! monotonically either; `reclaimed_jobs`/`reclaimed_conns` in the
//! `metrics` snapshot count what was swept.
//!
//! `max_tokens` is validated at parse time: 0 is rejected with a JSON error
//! line, and values above the server's cap (`max_tokens_cap`, default the
//! model's `max_seq`) are clamped — the completion reply then carries a
//! `"max_tokens_clamped"` field naming the cap applied.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;

use crate::engine::{Engine, NeuronPolicy, SamplingParams};
use crate::error::{Error, Result};
use crate::jsonx::{self, num, obj, Value};
use crate::tokenizer::Bpe;
use crate::{log_info, log_warn};

struct Job {
    conn_id: u64,
    client_req_id: f64,
    prompt_text: String,
    max_tokens: usize,
    /// the request asked for more than the server cap; the reply says so
    clamped: bool,
    sampling: SamplingParams,
    policy: Option<NeuronPolicy>,
}

/// Reader-thread -> scheduler messages. Malformed requests travel here too
/// (not straight to the writer): the scheduler owns the only reply sender,
/// so dropping it on `serve()` return still shuts the writer thread down.
/// Admin commands ride the same channel so snapshots see consistent engine
/// state (the scheduler owns the engine).
enum Inbound {
    Job(Job),
    Admin { conn_id: u64, cmd: String },
    /// pre-rendered JSON error line for a request that failed to parse
    Malformed { conn_id: u64, line: String },
    /// a connection died (reader EOF, or the writer evicted it): the
    /// scheduler reclaims its pending completions and request counter
    Disconnected { conn_id: u64 },
}

/// Writer-thread control: register a new connection's stream, or drop one
/// the scheduler learned is dead before a write to it ever failed.
enum WriterCtl {
    Register(u64, TcpStream),
    Drop(u64),
}

struct Reply {
    conn_id: u64,
    line: String,
}

/// Serve until `max_requests` completions (None = forever). Returns the
/// number served. Bind to port 0 to let the OS pick (the bound address is
/// logged and also sent to `ready_tx`). `max_tokens_cap` bounds any
/// request's `max_tokens` (0 = the model's `max_seq`); requests above it
/// are clamped, `max_tokens: 0` is rejected.
pub fn serve(
    mut engine: Engine,
    bpe: Arc<Bpe>,
    addr: &str,
    max_requests: Option<usize>,
    ready_tx: Option<mpsc::Sender<std::net::SocketAddr>>,
    max_tokens_cap: usize,
) -> Result<usize> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    log_info!("server", "listening on {local}");
    if let Some(tx) = ready_tx {
        let _ = tx.send(local);
    }
    let cap = if max_tokens_cap == 0 {
        engine.backend().config().max_seq
    } else {
        max_tokens_cap
    };

    let (job_tx, job_rx) = mpsc::channel::<Inbound>();
    let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
    let (writer_tx, writer_rx) = mpsc::channel::<WriterCtl>();
    // dead connections evicted by the writer thread (shared with the
    // scheduler so `{"cmd":"metrics"}` can report it)
    let evictions = Arc::new(AtomicU64::new(0));

    // connection acceptor -> per-connection reader threads
    let acceptor_job_tx = job_tx.clone();
    let sched_writer_tx = writer_tx.clone();
    std::thread::spawn(move || {
        let mut conn_id = 0u64;
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            conn_id += 1;
            let id = conn_id;
            // a failed clone loses one connection, not the acceptor
            let for_writer = match stream.try_clone() {
                Ok(s) => s,
                Err(e) => {
                    log_warn!("server", "conn {id}: stream clone failed ({e}); dropping");
                    continue;
                }
            };
            let _ = writer_tx.send(WriterCtl::Register(id, for_writer));
            let tx = acceptor_job_tx.clone();
            std::thread::spawn(move || {
                let reader = BufReader::new(stream);
                for line in reader.lines() {
                    let Ok(line) = line else { break };
                    if line.trim().is_empty() {
                        continue;
                    }
                    let msg = match parse_line(id, &line, cap) {
                        Ok(inbound) => inbound,
                        Err(e) => {
                            // malformed request: reply with a JSON error
                            // line, echoing the id when one parses
                            log_warn!("server", "bad request: {e}");
                            let req_id = jsonx::parse(line.trim())
                                .ok()
                                .and_then(|v| v.get("id").cloned())
                                .unwrap_or(Value::Null);
                            Inbound::Malformed {
                                conn_id: id,
                                line: obj(vec![
                                    ("id", req_id),
                                    ("error", Value::Str(e.to_string())),
                                ])
                                .to_json(),
                            }
                        }
                    };
                    if tx.send(msg).is_err() {
                        break;
                    }
                }
                // reader EOF: the peer is gone — let the scheduler sweep
                // this connection's pending completions and counters
                let _ = tx.send(Inbound::Disconnected { conn_id: id });
            });
        }
    });

    // writer thread: fan replies back to their connections, evicting a
    // connection on its first failed write (the peer hung up) so the map
    // cannot grow monotonically over a long-lived server's lifetime
    let writer_evictions = evictions.clone();
    let writer_job_tx = job_tx.clone();
    drop(job_tx);
    std::thread::spawn(move || {
        let mut conns: std::collections::HashMap<u64, TcpStream> =
            std::collections::HashMap::new();
        let mut apply = |conns: &mut std::collections::HashMap<u64, TcpStream>,
                         ctl: WriterCtl| match ctl {
            WriterCtl::Register(id, s) => {
                conns.insert(id, s);
            }
            WriterCtl::Drop(id) => {
                conns.remove(&id);
            }
        };
        loop {
            while let Ok(ctl) = writer_rx.try_recv() {
                apply(&mut conns, ctl);
            }
            match reply_rx.recv_timeout(std::time::Duration::from_millis(50)) {
                Ok(reply) => {
                    while let Ok(ctl) = writer_rx.try_recv() {
                        apply(&mut conns, ctl);
                    }
                    if let Some(s) = conns.get_mut(&reply.conn_id) {
                        let wrote = writeln!(s, "{}", reply.line).and_then(|_| s.flush());
                        if wrote.is_err() {
                            conns.remove(&reply.conn_id);
                            writer_evictions.fetch_add(1, Ordering::Relaxed);
                            // propagate: the scheduler holds state for this
                            // connection too
                            let _ = writer_job_tx
                                .send(Inbound::Disconnected { conn_id: reply.conn_id });
                        }
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => continue,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
    });

    // engine scheduler loop (this thread)
    let mut pending: std::collections::HashMap<u64, (u64, f64, bool)> =
        std::collections::HashMap::new();
    // protocol lines handled per connection (jobs + admin commands)
    let mut req_counts: std::collections::HashMap<u64, u64> =
        std::collections::HashMap::new();
    let mut served = 0usize;
    // scheduler-side reclamation counters (disconnect sweeps)
    let mut reclaimed_jobs = 0u64;
    let mut reclaimed_conns = 0u64;
    loop {
        // drain new jobs, admin commands + malformed-request error replies
        loop {
            match job_rx.try_recv() {
                Ok(Inbound::Job(job)) => {
                    *req_counts.entry(job.conn_id).or_insert(0) += 1;
                    let tokens = bpe.encode(&job.prompt_text);
                    let eid = engine.submit_with_policy(
                        tokens,
                        job.max_tokens,
                        job.sampling,
                        job.policy,
                    );
                    pending.insert(eid, (job.conn_id, job.client_req_id, job.clamped));
                }
                Ok(Inbound::Disconnected { conn_id }) => {
                    // sweep everything this connection still owns: its
                    // completions can never be delivered and its counter
                    // would otherwise live forever
                    let before = pending.len();
                    pending.retain(|_, &mut (cid, _, _)| cid != conn_id);
                    reclaimed_jobs += (before - pending.len()) as u64;
                    if req_counts.remove(&conn_id).is_some() {
                        reclaimed_conns += 1;
                    }
                    let _ = sched_writer_tx.send(WriterCtl::Drop(conn_id));
                }
                Ok(Inbound::Admin { conn_id, cmd }) => {
                    *req_counts.entry(conn_id).or_insert(0) += 1;
                    let line = match cmd.as_str() {
                        "metrics" => metrics_snapshot(
                            &engine,
                            served,
                            &req_counts,
                            evictions.load(Ordering::Relaxed),
                            reclaimed_jobs,
                            reclaimed_conns,
                        ),
                        "reset" => {
                            engine.metrics.reset();
                            req_counts.clear();
                            obj(vec![
                                ("ok", Value::Bool(true)),
                                ("cmd", Value::Str("reset".into())),
                            ])
                            .to_json()
                        }
                        other => obj(vec![(
                            "error",
                            Value::Str(format!("unknown cmd `{other}`")),
                        )])
                        .to_json(),
                    };
                    let _ = reply_tx.send(Reply { conn_id, line });
                }
                Ok(Inbound::Malformed { conn_id, line }) => {
                    let _ = reply_tx.send(Reply { conn_id, line });
                }
                Err(mpsc::TryRecvError::Empty) => break,
                Err(mpsc::TryRecvError::Disconnected) => return Ok(served),
            }
        }
        if !engine.has_work() {
            std::thread::sleep(std::time::Duration::from_millis(5));
            continue;
        }
        for done in engine.step()? {
            if let Some((conn_id, req_id, clamped)) = pending.remove(&done.id) {
                let text = bpe.decode(&done.tokens);
                let mut fields = vec![
                    ("id", Value::Num(req_id)),
                    ("text", Value::Str(text)),
                    ("tokens", Value::Num(done.tokens.len() as f64)),
                    ("prefill_ms", Value::Num(done.prefill_ms)),
                    ("queue_ms", Value::Num(done.queue_ms)),
                    ("total_ms", Value::Num(done.total_ms)),
                    // per-request sparsity observability: with per-slot
                    // masks these are THIS request's numbers, not the
                    // batch's (null density = no row ever ran sparse)
                    (
                        "mask_density",
                        done.mask_density.map(Value::Num).unwrap_or(Value::Null),
                    ),
                    ("enforced_rows", Value::Num(done.enforced_rows as f64)),
                    ("fallbacks", Value::Num(done.fallbacks as f64)),
                    (
                        "finish",
                        Value::Str(format!("{:?}", done.finish).to_lowercase()),
                    ),
                ];
                if clamped {
                    // the request asked past the cap; say what was applied
                    fields.push(("max_tokens_clamped", num(cap as f64)));
                }
                let line = obj(fields).to_json();
                let _ = reply_tx.send(Reply { conn_id, line });
                served += 1;
                if let Some(max) = max_requests {
                    if served >= max {
                        log_info!(
                            "server",
                            "served {served} requests; {}",
                            engine.metrics.report()
                        );
                        return Ok(served);
                    }
                }
            }
        }
    }
}

/// One `{"cmd":"metrics"}` reply line: the engine's full metrics snapshot
/// (counters, latency summaries, per-slot + per-layer series) plus the
/// server-level view (queue depth, active slots, per-connection counters,
/// writer evictions, scheduler reclamations).
fn metrics_snapshot(
    engine: &Engine,
    served: usize,
    req_counts: &std::collections::HashMap<u64, u64>,
    evictions: u64,
    reclaimed_jobs: u64,
    reclaimed_conns: u64,
) -> String {
    let mut ids: Vec<u64> = req_counts.keys().copied().collect();
    ids.sort_unstable();
    let connections: Vec<Value> = ids
        .iter()
        .map(|id| {
            obj(vec![
                ("conn", num(*id as f64)),
                ("requests", num(req_counts[id] as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("engine", engine.metrics.to_json()),
        (
            "server",
            obj(vec![
                ("served", num(served as f64)),
                ("queue_depth", num(engine.queue_len() as f64)),
                ("active", num(engine.active_count() as f64)),
                ("evictions", num(evictions as f64)),
                ("reclaimed_jobs", num(reclaimed_jobs as f64)),
                ("reclaimed_conns", num(reclaimed_conns as f64)),
                ("connections", Value::Arr(connections)),
            ]),
        ),
    ])
    .to_json()
}

/// Parse one protocol line: a `cmd` key makes it an admin command, anything
/// else must be a generation request. `max_tokens` is validated here:
/// 0 is an error (the request could never produce a token), values above
/// `max_tokens_cap` are clamped and flagged.
fn parse_line(conn_id: u64, line: &str, max_tokens_cap: usize) -> Result<Inbound> {
    let v = jsonx::parse(line.trim())?;
    if let Some(c) = v.get("cmd") {
        let cmd = c
            .as_str()
            .ok_or_else(|| Error::Config("`cmd` is not a string".into()))?
            .to_string();
        return Ok(Inbound::Admin { conn_id, cmd });
    }
    let policy = match v.get("policy") {
        None | Some(Value::Null) => None,
        Some(p) => {
            let spec = p
                .as_str()
                .ok_or_else(|| Error::Config("`policy` is not a string".into()))?;
            Some(NeuronPolicy::parse(spec)?)
        }
    };
    let mut max_tokens = v.get("max_tokens").and_then(|x| x.as_usize()).unwrap_or(16);
    if max_tokens == 0 {
        return Err(Error::Config("`max_tokens` must be >= 1".into()));
    }
    let clamped = max_tokens > max_tokens_cap;
    if clamped {
        max_tokens = max_tokens_cap;
    }
    Ok(Inbound::Job(Job {
        conn_id,
        client_req_id: v.get("id").and_then(|x| x.as_f64()).unwrap_or(0.0),
        prompt_text: v.str_of("prompt")?,
        max_tokens,
        clamped,
        sampling: SamplingParams {
            temperature: v.get("temperature").and_then(|x| x.as_f64()).unwrap_or(0.0),
            top_k: v.get("top_k").and_then(|x| x.as_usize()).unwrap_or(0),
            seed: v.get("seed").and_then(|x| x.as_i64()).unwrap_or(0) as u64,
        },
        policy,
    }))
}

/// Simple blocking client for examples/tests.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl Client {
    pub fn connect(addr: std::net::SocketAddr) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Client { stream, reader })
    }

    pub fn request(
        &mut self,
        id: u64,
        prompt: &str,
        max_tokens: usize,
        temperature: f64,
    ) -> Result<Value> {
        let line = obj(vec![
            ("id", Value::Num(id as f64)),
            ("prompt", Value::Str(prompt.to_string())),
            ("max_tokens", Value::Num(max_tokens as f64)),
            ("temperature", Value::Num(temperature)),
        ])
        .to_json();
        self.send_line(&line)?;
        self.recv()
    }

    /// Send one admin command (`metrics`, `reset`, ...) and read the reply.
    pub fn cmd(&mut self, cmd: &str) -> Result<Value> {
        let line = obj(vec![("cmd", Value::Str(cmd.to_string()))]).to_json();
        self.send_line(&line)?;
        self.recv()
    }

    /// Send one raw protocol line (tests use this to exercise the
    /// malformed-request path).
    pub fn send_line(&mut self, line: &str) -> Result<()> {
        writeln!(self.stream, "{line}")?;
        self.stream.flush()?;
        Ok(())
    }

    /// Read the next JSON reply line.
    pub fn recv(&mut self) -> Result<Value> {
        let mut resp = String::new();
        self.reader.read_line(&mut resp)?;
        if resp.is_empty() {
            return Err(Error::msg("server closed connection"));
        }
        jsonx::parse(resp.trim())
    }
}
