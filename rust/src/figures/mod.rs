//! Figure/table output plumbing: CSV writers + shared experiment helpers
//! used by examples/ and benches/ to regenerate the paper's plots.

use std::io::Write as _;
use std::path::PathBuf;

use crate::error::Result;

/// Where figure CSVs land: `<runs>/figures/`.
pub fn figures_dir() -> PathBuf {
    crate::default_runs_dir().join("figures")
}

/// Simple CSV writer with a fixed header.
pub struct Csv {
    file: std::fs::File,
    pub path: PathBuf,
    pub cols: usize,
}

impl Csv {
    pub fn create(name: &str, headers: &[&str]) -> Result<Csv> {
        let dir = figures_dir();
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(name);
        let mut file = std::fs::File::create(&path)?;
        writeln!(file, "{}", headers.join(","))?;
        Ok(Csv {
            file,
            path,
            cols: headers.len(),
        })
    }

    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        debug_assert_eq!(cells.len(), self.cols);
        writeln!(self.file, "{}", cells.join(","))?;
        Ok(())
    }

    pub fn rowf(&mut self, cells: &[f64]) -> Result<()> {
        self.row(&cells.iter().map(|c| format!("{c}")).collect::<Vec<_>>())
    }

    pub fn done(self) -> PathBuf {
        println!("  wrote {}", self.path.display());
        self.path
    }
}

/// Shared run-artifact locations so examples can hand results to each other
/// (e.g. relufication checkpoints feeding the spec-decode example).
pub fn shared_checkpoint(model_id: &str, tag: &str) -> PathBuf {
    checkpoint_path(&crate::default_runs_dir(), model_id, tag)
}

/// Checkpoint path for a model id under a runs dir (host-safe: also used by
/// the `--backend host` serving path, so it cannot live in the `xla`-gated
/// train module).
pub fn checkpoint_path(runs: &std::path::Path, model_id: &str, tag: &str) -> PathBuf {
    runs.join("checkpoints").join(format!("{model_id}.{tag}.ckpt"))
}

pub fn shared_tokenizer(vocab: usize) -> PathBuf {
    crate::default_runs_dir().join(format!("tokenizer.v{vocab}.txt"))
}

pub fn shared_dataset(vocab: usize) -> PathBuf {
    crate::default_runs_dir().join(format!("dataset.v{vocab}.bin"))
}

/// Ensure (tokenizer, dataset) exist for a vocab size, building them from
/// synthlang if missing; all experiments share these so checkpoints stay
/// compatible.
pub fn ensure_data(
    vocab: usize,
    target_chars: usize,
    seed: u64,
) -> Result<(crate::data::Dataset, crate::tokenizer::Bpe)> {
    let tok_path = shared_tokenizer(vocab);
    let ds_path = shared_dataset(vocab);
    if tok_path.exists() && ds_path.exists() {
        let bpe = crate::tokenizer::Bpe::load(&tok_path)?;
        let ds = crate::data::Dataset::load_tokens(&ds_path)?;
        if ds.train.len() * 3 >= target_chars {
            // cached dataset is big enough (tokens ≈ chars / ~3)
            return Ok((ds, bpe));
        }
    }
    let (ds, bpe) = crate::data::Dataset::synthetic(seed, target_chars, vocab)?;
    bpe.save(&tok_path)?;
    ds.save_tokens(&ds_path)?;
    Ok((ds, bpe))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_rows() {
        std::env::set_var("RSB_RUNS", std::env::temp_dir().join("rsb_fig_test"));
        let mut c = Csv::create("test.csv", &["a", "b"]).unwrap();
        c.rowf(&[1.0, 2.5]).unwrap();
        let p = c.done();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n");
        std::env::remove_var("RSB_RUNS");
    }
}
