//! Minimal JSON substrate (parser + writer).
//!
//! The offline crate set has no `serde`/`serde_json`, so the manifest,
//! config files, run logs and the TCP server protocol all go through this
//! hand-rolled implementation. It supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, bools, null) and preserves object
//! key order.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Key-ordered object (insertion order preserved).
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Value> {
        self.get(key)
            .ok_or_else(|| Error::Manifest(format!("missing key `{key}`")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Convenience: `[1,2,3]` -> `vec![1,2,3]` as usize.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    pub fn str_of(&self, key: &str) -> Result<String> {
        Ok(self
            .req(key)?
            .as_str()
            .ok_or_else(|| Error::Manifest(format!("`{key}` is not a string")))?
            .to_string())
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| Error::Manifest(format!("`{key}` is not a number")))
    }

    pub fn f64_of(&self, key: &str) -> Result<f64> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| Error::Manifest(format!("`{key}` is not a number")))
    }

    pub fn bool_of(&self, key: &str) -> Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| Error::Manifest(format!("`{key}` is not a bool")))
    }

    /// Serialize to a compact JSON string.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write_to(&mut out);
        out
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_to(out);
                }
                out.push(']');
            }
            Value::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for constructing objects ergonomically.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: impl Into<String>) -> Value {
    Value::Str(v.into())
}

pub fn arr_f64(items: &[f64]) -> Value {
    Value::Arr(items.iter().map(|x| Value::Num(*x)).collect())
}

pub fn arr_usize(items: &[usize]) -> Value {
    Value::Arr(items.iter().map(|x| Value::Num(*x as f64)).collect())
}

fn write_escaped(out: &mut String, sv: &str) {
    out.push('"');
    for c in sv.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(input: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => break,
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
        Ok(Value::Obj(pairs))
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => break,
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
        Ok(Value::Arr(items))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => break,
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // surrogate pair handling
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let lo = self.hex4()?;
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            out.push(
                                char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequence
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return Err(self.err("bad utf8")),
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump().ok_or_else(|| self.err("bad utf8"))?;
                    }
                    let sref = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(sref);
                }
            }
        }
        Ok(out)
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

/// Parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> Result<Value> {
    let text = std::fs::read_to_string(path)?;
    parse(&text)
}

/// Convert an object into a string->Value map (for schema-ish access).
pub fn to_map(v: &Value) -> BTreeMap<String, Value> {
    match v {
        Value::Obj(pairs) => pairs.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = parse(r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.str_of("b").unwrap(), "x\ny");
        assert_eq!(v.bool_of("c").unwrap(), true);
        let back = parse(&v.to_json()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"o": {"k": [{"x": 1}]}}"#).unwrap();
        assert_eq!(
            v.req("o").unwrap().req("k").unwrap().as_arr().unwrap()[0]
                .usize_of("x")
                .unwrap(),
            1
        );
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""A😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\u{1F600}");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nope").is_err());
        assert!(parse(r#"{"a":1} extra"#).is_err());
    }

    #[test]
    fn number_formats() {
        for (txt, want) in [("0", 0.0), ("-12", -12.0), ("3.5", 3.5), ("1e3", 1000.0)] {
            assert_eq!(parse(txt).unwrap().as_f64().unwrap(), want);
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Value::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(parse(&v.to_json()).unwrap(), v);
    }
}
