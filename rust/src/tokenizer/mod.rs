//! Byte-pair-encoding tokenizer substrate: trainer, encoder/decoder, vocab
//! serialization. Built from scratch (the paper's pipeline assumes a
//! pretrained tokenizer; we train ours on the synthetic corpus).
//!
//! Special tokens: 0 = BOS/PAD ("<s>"), 1 = EOS ("</s>"), 2 = UNK.
//! Base alphabet: every byte value seen in the training text; merges are
//! learned greedily by pair frequency up to `vocab_size`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Error, Result};

pub const BOS: u32 = 0;
pub const EOS: u32 = 1;
pub const UNK: u32 = 2;
pub const N_SPECIAL: u32 = 3;

#[derive(Debug, Clone)]
pub struct Bpe {
    /// token id -> byte string
    pub pieces: Vec<Vec<u8>>,
    /// learned merges in priority order: (left id, right id) -> merged id
    pub merges: Vec<(u32, u32, u32)>,
    merge_rank: BTreeMap<(u32, u32), (usize, u32)>,
    byte_to_id: BTreeMap<u8, u32>,
}

impl Bpe {
    /// Train a BPE vocabulary of `vocab_size` tokens on `text`.
    pub fn train(text: &str, vocab_size: usize) -> Result<Bpe> {
        if vocab_size < (N_SPECIAL as usize) + 8 {
            return Err(Error::Tokenizer("vocab too small".into()));
        }
        let mut pieces: Vec<Vec<u8>> =
            vec![b"<s>".to_vec(), b"</s>".to_vec(), b"<unk>".to_vec()];
        let mut byte_to_id = BTreeMap::new();
        // base alphabet: bytes in appearance order, deterministically sorted
        let mut seen: Vec<u8> = text.bytes().collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        seen.sort();
        for b in seen {
            byte_to_id.insert(b, pieces.len() as u32);
            pieces.push(vec![b]);
        }
        // initial token stream over "words" (split on spaces, space kept as
        // prefix marker byte like GPT-2's leading-space convention)
        let words = split_words(text);
        let word_tokens: Vec<Vec<u32>> = words
            .iter()
            .map(|w| w.bytes().map(|b| byte_to_id[&b]).collect())
            .collect();
        let mut word_counts: BTreeMap<Vec<u32>, usize> = BTreeMap::new();
        for wt in &word_tokens {
            *word_counts.entry(wt.clone()).or_insert(0) += 1;
        }
        drop(word_tokens);

        let mut merges = Vec::new();
        while pieces.len() < vocab_size {
            // count adjacent pairs over unique words weighted by frequency
            let mut pair_counts: BTreeMap<(u32, u32), usize> = BTreeMap::new();
            for (wt, c) in &word_counts {
                for win in wt.windows(2) {
                    *pair_counts.entry((win[0], win[1])).or_insert(0) += c;
                }
            }
            let Some((&pair, &count)) = pair_counts.iter().max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if count < 2 {
                break; // nothing useful left to merge
            }
            let new_id = pieces.len() as u32;
            let mut merged_piece = pieces[pair.0 as usize].clone();
            merged_piece.extend_from_slice(&pieces[pair.1 as usize]);
            pieces.push(merged_piece);
            merges.push((pair.0, pair.1, new_id));
            // apply the merge to every word
            let mut next_counts: BTreeMap<Vec<u32>, usize> = BTreeMap::new();
            for (wt, c) in word_counts {
                let merged = apply_merge(&wt, pair, new_id);
                *next_counts.entry(merged).or_insert(0) += c;
            }
            word_counts = next_counts;
        }
        let merge_rank = merges
            .iter()
            .enumerate()
            .map(|(rank, &(a, b, id))| ((a, b), (rank, id)))
            .collect();
        Ok(Bpe {
            pieces,
            merges,
            merge_rank,
            byte_to_id,
        })
    }

    pub fn vocab_size(&self) -> usize {
        self.pieces.len()
    }

    /// Encode text to token ids (no BOS/EOS added).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::new();
        for word in split_words(text) {
            let mut toks: Vec<u32> = word
                .bytes()
                .map(|b| self.byte_to_id.get(&b).copied().unwrap_or(UNK))
                .collect();
            // repeatedly apply the highest-priority applicable merge
            loop {
                let mut best: Option<(usize, usize, u32)> = None; // (rank, pos, id)
                for (i, win) in toks.windows(2).enumerate() {
                    if let Some(&(rank, id)) = self.merge_rank.get(&(win[0], win[1])) {
                        if best.map_or(true, |(br, _, _)| rank < br) {
                            best = Some((rank, i, id));
                        }
                    }
                }
                match best {
                    Some((_, pos, id)) => {
                        toks[pos] = id;
                        toks.remove(pos + 1);
                    }
                    None => break,
                }
            }
            out.extend(toks);
        }
        out
    }

    /// Decode token ids back to text.
    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if id < N_SPECIAL {
                continue;
            }
            if let Some(p) = self.pieces.get(id as usize) {
                bytes.extend_from_slice(p);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Serialize to a text file (one piece per line, hex-encoded, then merges).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = String::new();
        out.push_str(&format!("bpe {}\n", self.pieces.len()));
        for p in &self.pieces {
            for b in p {
                out.push_str(&format!("{b:02x}"));
            }
            out.push('\n');
        }
        out.push_str(&format!("merges {}\n", self.merges.len()));
        for &(a, b, id) in &self.merges {
            out.push_str(&format!("{a} {b} {id}\n"));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Bpe> {
        let text = std::fs::read_to_string(path)?;
        let mut lines = text.lines();
        let head = lines
            .next()
            .ok_or_else(|| Error::Tokenizer("empty vocab file".into()))?;
        let n: usize = head
            .strip_prefix("bpe ")
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Tokenizer("bad header".into()))?;
        let mut pieces = Vec::with_capacity(n);
        for _ in 0..n {
            let line = lines
                .next()
                .ok_or_else(|| Error::Tokenizer("truncated pieces".into()))?;
            let mut bytes = Vec::with_capacity(line.len() / 2);
            let lb = line.as_bytes();
            for c in lb.chunks(2) {
                let s = std::str::from_utf8(c).map_err(|_| Error::Tokenizer("bad hex".into()))?;
                bytes.push(
                    u8::from_str_radix(s, 16).map_err(|_| Error::Tokenizer("bad hex".into()))?,
                );
            }
            pieces.push(bytes);
        }
        let mhead = lines
            .next()
            .ok_or_else(|| Error::Tokenizer("missing merges".into()))?;
        let m: usize = mhead
            .strip_prefix("merges ")
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| Error::Tokenizer("bad merges header".into()))?;
        let mut merges = Vec::with_capacity(m);
        for _ in 0..m {
            let line = lines
                .next()
                .ok_or_else(|| Error::Tokenizer("truncated merges".into()))?;
            let mut it = line.split(' ');
            let a = it.next().and_then(|s| s.parse().ok());
            let b = it.next().and_then(|s| s.parse().ok());
            let id = it.next().and_then(|s| s.parse().ok());
            match (a, b, id) {
                (Some(a), Some(b), Some(id)) => merges.push((a, b, id)),
                _ => return Err(Error::Tokenizer("bad merge line".into())),
            }
        }
        let merge_rank = merges
            .iter()
            .enumerate()
            .map(|(rank, &(a, b, id))| ((a, b), (rank, id)))
            .collect();
        let mut byte_to_id = BTreeMap::new();
        for (i, p) in pieces.iter().enumerate() {
            if p.len() == 1 && i >= N_SPECIAL as usize {
                byte_to_id.entry(p[0]).or_insert(i as u32);
            }
        }
        Ok(Bpe {
            pieces,
            merges,
            merge_rank,
            byte_to_id,
        })
    }
}

fn apply_merge(toks: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(toks.len());
    let mut i = 0;
    while i < toks.len() {
        if i + 1 < toks.len() && toks[i] == pair.0 && toks[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(toks[i]);
            i += 1;
        }
    }
    out
}

/// Split text into "words" keeping each word's leading space (GPT-2 style):
/// "a bc d" -> ["a", " bc", " d"].
fn split_words(text: &str) -> Vec<String> {
    let mut words = Vec::new();
    let mut cur = String::new();
    for ch in text.chars() {
        if ch == ' ' {
            if !cur.is_empty() && !cur.ends_with(' ') {
                words.push(std::mem::take(&mut cur));
            }
            cur.push(' ');
        } else {
            cur.push(ch);
        }
    }
    if !cur.is_empty() {
        words.push(cur);
    }
    words
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "the cat sat on the mat . the cat ran . a dog sat on a log . \
                          the dog and the cat sat together . the mat was flat .";

    #[test]
    fn roundtrip_exact() {
        let bpe = Bpe::train(SAMPLE, 80).unwrap();
        let ids = bpe.encode(SAMPLE);
        assert_eq!(bpe.decode(&ids), SAMPLE);
    }

    #[test]
    fn merges_compress() {
        let bpe_small = Bpe::train(SAMPLE, 28).unwrap();
        let bpe_big = Bpe::train(SAMPLE, 120).unwrap();
        let n_small = bpe_small.encode(SAMPLE).len();
        let n_big = bpe_big.encode(SAMPLE).len();
        assert!(n_big < n_small, "{n_big} !< {n_small}");
    }

    #[test]
    fn unknown_bytes_map_to_unk() {
        let bpe = Bpe::train("abc abc", 20).unwrap();
        let ids = bpe.encode("xyz");
        assert!(ids.iter().all(|&t| t == UNK));
    }

    #[test]
    fn save_load_identical() {
        let bpe = Bpe::train(SAMPLE, 64).unwrap();
        let dir = std::env::temp_dir().join(format!("rsb_bpe_{}", std::process::id()));
        let path = dir.join("vocab.txt");
        bpe.save(&path).unwrap();
        let loaded = Bpe::load(&path).unwrap();
        assert_eq!(bpe.pieces, loaded.pieces);
        assert_eq!(bpe.encode(SAMPLE), loaded.encode(SAMPLE));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn vocab_ids_in_range() {
        let bpe = Bpe::train(SAMPLE, 64).unwrap();
        let ids = bpe.encode(SAMPLE);
        assert!(ids.iter().all(|&t| (t as usize) < bpe.vocab_size()));
    }

    #[test]
    fn deterministic_training() {
        let a = Bpe::train(SAMPLE, 60).unwrap();
        let b = Bpe::train(SAMPLE, 60).unwrap();
        assert_eq!(a.pieces, b.pieces);
        assert_eq!(a.merges, b.merges);
    }
}
