//! Admin-protocol tests for the TCP server (ISSUE 6): a live server over
//! the host backend must answer `{"cmd":"metrics"}` with a full JSON
//! snapshot — engine counters, per-slot and per-layer series, server queue
//! depth and per-connection request counters — support `{"cmd":"reset"}`,
//! and reply with a JSON error line to unknown or malformed commands, all
//! without wedging the generation path. No PJRT anywhere in the process.

use std::sync::Arc;

use rsb::engine::{Engine, EngineConfig, NeuronPolicy};
use rsb::hostexec::HostBackend;
use rsb::jsonx::Value;
use rsb::runtime::artifact::ModelCfg;
use rsb::runtime::Tensor;
use rsb::util::rng::Rng;

/// Honor `PALLAS_LOG` in the test process (main.rs does this for the
/// binary): CI runs this suite with `PALLAS_LOG=debug,json` and validates
/// the captured stderr with tools/log_check.py.
fn init_logs() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(rsb::obs::log::init_from_env);
}

fn cfg() -> ModelCfg {
    init_logs();
    ModelCfg {
        size: "t".into(),
        arch: "opt".into(),
        act: "relu".into(),
        stage: 0,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 40,
        max_seq: 20,
        shift: 1.0,
        ffn_act: "relu".into(),
        gated: false,
        parallel_block: false,
        has_bias: true,
    }
}

#[test]
fn metrics_and_reset_over_live_tcp_server() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let server = std::thread::spawn(move || {
        let backend = HostBackend::random(cfg(), 0, 2, 6).unwrap();
        // a static enforced mask so the per-slot + per-layer series have
        // real enforced-row samples to report
        let mut rng = Rng::new(11);
        let bits: Vec<bool> = (0..2 * 32).map(|_| rng.chance(0.4)).collect();
        let ecfg = EngineConfig {
            policy: NeuronPolicy::Static(Tensor::mask_from_bits(vec![2, 32], &bits).unwrap()),
            ..EngineConfig::default()
        };
        let engine = Engine::new(Box::new(backend), ecfg).unwrap();
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", Some(3), Some(ready_tx), 0)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();

    // one generation request populates the engine series
    let resp = client.request(1, "ab ba", 4, 0.0).unwrap();
    assert_eq!(resp.get("tokens").and_then(Value::as_usize), Some(4));

    // -- {"cmd":"metrics"}: full snapshot ---------------------------------
    let snap = client.cmd("metrics").unwrap();
    let engine = snap.req("engine").unwrap();
    assert!(engine.usize_of("steps").unwrap() > 0);
    assert_eq!(engine.usize_of("tokens_generated").unwrap(), 4);
    assert!(engine.f64_of("tokens_per_sec").unwrap() > 0.0);
    // per-slot series: the serving slot enforced its static mask
    let slots = engine.req("per_slot").unwrap().as_arr().unwrap();
    assert!(!slots.is_empty(), "per-slot series missing");
    assert!(slots[0].usize_of("enforced_rows").unwrap() > 0);
    // per-layer series: one density histogram per layer, fed by the same
    // enforced rows
    let per_layer = engine.req("per_layer").unwrap();
    assert_eq!(per_layer.usize_of("n_layers").unwrap(), 2);
    let layers = per_layer.req("layers").unwrap().as_arr().unwrap();
    assert_eq!(layers.len(), 2);
    for l in layers {
        assert!(l.req("density").unwrap().usize_of("total").unwrap() > 0);
    }
    let wmean = per_layer.f64_of("weighted_mean_density").unwrap();
    assert!(wmean > 0.0 && wmean < 1.0);
    // server-level view: queue drained, this connection counted (the
    // request + this metrics command), no writer evictions
    let srv = snap.req("server").unwrap();
    assert_eq!(srv.usize_of("served").unwrap(), 1);
    assert_eq!(srv.usize_of("queue_depth").unwrap(), 0);
    assert_eq!(srv.usize_of("evictions").unwrap(), 0);
    let conns = srv.req("connections").unwrap().as_arr().unwrap();
    assert_eq!(conns.len(), 1);
    assert_eq!(conns[0].usize_of("requests").unwrap(), 2);

    // -- {"cmd":"reset"}: zeroes the engine series ------------------------
    let resp = client.cmd("reset").unwrap();
    assert!(resp.bool_of("ok").unwrap());
    let snap = client.cmd("metrics").unwrap();
    let engine = snap.req("engine").unwrap();
    assert_eq!(engine.usize_of("tokens_generated").unwrap(), 0);
    let per_layer = engine.req("per_layer").unwrap();
    // geometry survives the reset even though the series are empty
    assert_eq!(per_layer.usize_of("n_layers").unwrap(), 2);
    assert_eq!(per_layer.f64_of("weighted_mean_density").unwrap(), 0.0);
    // the connection counter was reset too (this metrics cmd re-added it)
    let conns = snap.req("server").unwrap().req("connections").unwrap();
    assert_eq!(conns.as_arr().unwrap()[0].usize_of("requests").unwrap(), 1);

    // -- error paths ------------------------------------------------------
    let resp = client.cmd("bogus").unwrap();
    assert!(resp.str_of("error").unwrap().contains("unknown cmd"));
    client.send_line("{\"cmd\": 5}").unwrap();
    let resp = client.recv().unwrap();
    assert!(resp.str_of("error").unwrap().contains("cmd"));

    // the generation path still works after the admin traffic
    for i in 2..4 {
        let resp = client.request(i, "ab", 2, 0.0).unwrap();
        assert_eq!(resp.get("id").and_then(Value::as_i64), Some(i as i64));
    }
    assert_eq!(server.join().unwrap().unwrap(), 3);
}

/// A client that disconnects mid-request must not leave state behind: the
/// scheduler sweeps its pending completion and request counter (ISSUE 7 —
/// `pending`/`req_counts` grew monotonically before), and the metrics
/// snapshot reports what was reclaimed.
#[test]
fn disconnect_mid_request_reclaims_scheduler_state() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let server = std::thread::spawn(move || {
        // a heavier geometry than the other tests: A's orphaned request
        // must still be decoding when its disconnect reaches the scheduler
        let mut c = cfg();
        c.d_model = 64;
        c.n_heads = 4;
        c.d_ff = 256;
        c.max_seq = 64;
        let backend = HostBackend::random(c, 0, 2, 6).unwrap();
        let engine = Engine::new(Box::new(backend), EngineConfig::default()).unwrap();
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", Some(1), Some(ready_tx), 0)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");

    // connection A: submit a long request, then hang up before the reply
    {
        let mut a = rsb::server::Client::connect(addr).unwrap();
        a.send_line(
            "{\"id\": 1, \"prompt\": \"ab ba\", \"max_tokens\": 48, \"temperature\": 0.0}",
        )
        .unwrap();
    } // A dropped: reader EOF -> Disconnected -> scheduler sweep

    // connection B: watch the sweep land, then serve one real request
    let mut b = rsb::server::Client::connect(addr).unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let (mut jobs, mut conns) = (0, 0);
    while std::time::Instant::now() < deadline {
        let snap = b.cmd("metrics").unwrap();
        let srv = snap.req("server").unwrap();
        jobs = srv.usize_of("reclaimed_jobs").unwrap();
        conns = srv.usize_of("reclaimed_conns").unwrap();
        if jobs >= 1 && conns >= 1 {
            // A's counter is gone from the per-connection list too
            let listed = srv.req("connections").unwrap().as_arr().unwrap().len();
            assert_eq!(listed, 1, "only B should remain in connections");
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(10));
    }
    assert!(jobs >= 1, "pending completion was not reclaimed");
    assert!(conns >= 1, "req_counts entry was not reclaimed");
    let resp = b.request(2, "ab", 2, 0.0).unwrap();
    assert_eq!(resp.get("tokens").and_then(Value::as_usize), Some(2));
    // A's orphaned job never counts as served
    assert_eq!(server.join().unwrap().unwrap(), 1);
}

/// `max_tokens` validation (ISSUE 7): 0 is rejected with a JSON error
/// line, values above the server's cap are clamped and the reply says so.
#[test]
fn max_tokens_zero_rejected_and_oversize_clamped() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let server = std::thread::spawn(move || {
        let backend = HostBackend::random(cfg(), 0, 2, 6).unwrap();
        let engine = Engine::new(Box::new(backend), EngineConfig::default()).unwrap();
        // cap requests at 5 generated tokens
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", Some(1), Some(ready_tx), 5)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();

    // max_tokens 0: a JSON error line echoing the request id, nothing runs
    client
        .send_line("{\"id\": 7, \"prompt\": \"ab\", \"max_tokens\": 0}")
        .unwrap();
    let resp = client.recv().unwrap();
    assert_eq!(resp.get("id").and_then(Value::as_i64), Some(7));
    assert!(resp.str_of("error").unwrap().contains("max_tokens"));

    // max_tokens far past the cap: clamped to 5, and the reply names the cap
    let resp = client.request(8, "ab ba", 10_000, 0.0).unwrap();
    assert_eq!(resp.get("tokens").and_then(Value::as_usize), Some(5));
    assert_eq!(resp.get("max_tokens_clamped").and_then(Value::as_usize), Some(5));
    assert_eq!(
        resp.str_of("finish").unwrap(),
        "maxtokens",
        "the clamp is what ended the request"
    );
    assert_eq!(server.join().unwrap().unwrap(), 1);
}

/// `"stream": true` delivers one JSON line per generated token — id echoed,
/// indices in order, each with its decoded text piece — before the final
/// completion line repeats the full text.
#[test]
fn streaming_delivers_per_token_lines_then_completion() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let server = std::thread::spawn(move || {
        let backend = HostBackend::random(cfg(), 0, 2, 6).unwrap();
        let engine = Engine::new(Box::new(backend), EngineConfig::default()).unwrap();
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", Some(1), Some(ready_tx), 0)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();
    client
        .send_line("{\"id\": 3, \"prompt\": \"ab ba\", \"max_tokens\": 4, \"stream\": true}")
        .unwrap();
    let mut streamed = Vec::new();
    for i in 0..4 {
        let line = client.recv().unwrap();
        assert_eq!(line.get("id").and_then(Value::as_i64), Some(3));
        assert_eq!(line.usize_of("index").unwrap(), i);
        line.str_of("text").expect("token lines carry decoded text");
        streamed.push(line.usize_of("token").unwrap() as u32);
    }
    let fin = client.recv().unwrap();
    assert_eq!(fin.get("id").and_then(Value::as_i64), Some(3));
    assert_eq!(fin.usize_of("tokens").unwrap(), 4);
    assert_eq!(fin.str_of("finish").unwrap(), "maxtokens");
    // the streamed tokens are exactly the completion's token sequence
    assert_eq!(bpe.decode(&streamed), fin.str_of("text").unwrap());
    assert_eq!(server.join().unwrap().unwrap(), 1);
}

/// An idle scheduler parks on its inbound channel and admits the next
/// request at channel-wakeup latency — no sleep-tick poll loop between a
/// request's arrival and its admission. Pinned by the measured queue wait
/// over a sequence of requests that each find the server idle: a poll-tick
/// scheduler (the old 5 ms sleep) would put ~half a tick in every sample.
#[test]
fn idle_server_admits_at_wakeup_latency_not_poll_tick() {
    use std::sync::mpsc;
    let n = 16usize;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let server = std::thread::spawn(move || {
        let backend = HostBackend::random(cfg(), 0, 2, 6).unwrap();
        let engine = Engine::new(Box::new(backend), EngineConfig::default()).unwrap();
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", Some(n), Some(ready_tx), 0)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();
    let mut waits = Vec::with_capacity(n);
    for i in 0..n {
        // sequential single-token requests: the engine fully drains (and
        // the scheduler re-parks) between every pair
        let resp = client.request(i as u64, "ab", 1, 0.0).unwrap();
        waits.push(resp.f64_of("queue_ms").unwrap());
    }
    let mean = waits.iter().sum::<f64>() / n as f64;
    assert!(
        mean < 1.5,
        "idle admission waited {mean:.3}ms on average ({waits:?}) — \
         the scheduler is polling, not blocking"
    );
    assert_eq!(server.join().unwrap().unwrap(), n);
}

/// A request whose `deadline_ms` expires mid-flight is evicted wherever it
/// is (queued, prefilling or decoding), its reply says
/// `"finish": "deadline"` with whatever was generated by then, and the
/// engine counts the eviction in its metrics.
#[test]
fn deadline_expiry_evicts_and_reports_deadline_finish() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let server = std::thread::spawn(move || {
        // heavy enough that 48 tokens cannot finish inside the deadline
        let mut c = cfg();
        c.d_model = 64;
        c.n_heads = 4;
        c.d_ff = 256;
        c.max_seq = 64;
        let backend = HostBackend::random(c, 0, 2, 6).unwrap();
        let engine = Engine::new(Box::new(backend), EngineConfig::default()).unwrap();
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", Some(2), Some(ready_tx), 0)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();
    client
        .send_line(
            "{\"id\": 9, \"prompt\": \"ab ba\", \"max_tokens\": 48, \"deadline_ms\": 1}",
        )
        .unwrap();
    let resp = client.recv().unwrap();
    assert_eq!(resp.get("id").and_then(Value::as_i64), Some(9));
    assert_eq!(resp.str_of("finish").unwrap(), "deadline");
    assert!(
        resp.usize_of("tokens").unwrap() < 48,
        "a deadline eviction cannot have produced the full generation"
    );
    let snap = client.cmd("metrics").unwrap();
    let engine = snap.req("engine").unwrap();
    assert_eq!(engine.usize_of("deadline_evictions").unwrap(), 1);
    // the slot (and its KV row) is free again: a normal request completes
    let resp = client.request(10, "ab", 2, 0.0).unwrap();
    assert_eq!(resp.str_of("finish").unwrap(), "maxtokens");
    assert_eq!(server.join().unwrap().unwrap(), 2);
}

/// With the engine's `queue_cap` set, a burst past slots + cap gets
/// immediate `{"error": ..., "backpressure": true}` rejections instead of
/// unbounded queueing, and the rejections land in the engine metrics.
#[test]
fn queue_cap_rejects_burst_with_backpressure_error() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let _server = std::thread::spawn(move || {
        // 2 decode slots + a queue capped at 1: a burst of 8 long requests
        // must overflow (the slowest legal drain frees one queue place per
        // ~40-step generation, far slower than the burst lands)
        let mut c = cfg();
        c.d_model = 64;
        c.n_heads = 4;
        c.d_ff = 256;
        c.max_seq = 64;
        let backend = HostBackend::random(c, 0, 2, 6).unwrap();
        let ecfg = EngineConfig {
            queue_cap: 1,
            ..EngineConfig::default()
        };
        let engine = Engine::new(Box::new(backend), ecfg).unwrap();
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", None, Some(ready_tx), 0)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();
    for i in 0..8 {
        client
            .send_line(&format!(
                "{{\"id\": {i}, \"prompt\": \"ab ba\", \"max_tokens\": 40}}"
            ))
            .unwrap();
    }
    // every request gets exactly one reply line: a completion or an
    // immediate backpressure rejection
    let (mut rejected, mut completed) = (0usize, 0usize);
    for _ in 0..8 {
        let resp = client.recv().unwrap();
        if matches!(resp.get("backpressure"), Some(Value::Bool(true))) {
            assert!(resp.str_of("error").unwrap().contains("queue full"));
            rejected += 1;
        } else {
            assert_eq!(resp.str_of("finish").unwrap(), "maxtokens");
            completed += 1;
        }
    }
    assert!(rejected >= 1, "an 8-deep burst must overflow cap 1");
    assert!(completed >= 1, "accepted requests must still complete");
    assert_eq!(rejected + completed, 8);
    let snap = client.cmd("metrics").unwrap();
    let engine = snap.req("engine").unwrap();
    assert_eq!(
        engine.usize_of("backpressure_rejections").unwrap(),
        rejected
    );
}

/// ISSUE 9: `{"cmd":"reset"}` must zero the serving gauges introduced with
/// continuous batching — `deadline_evictions`, the KV-page high-water mark
/// (re-anchored, not resurrected from the pool on the next step), the
/// `admissions_per_step` histogram — and the latency sketches, while the
/// pool geometry gauge (`kv_pages_total`) survives.
#[test]
fn reset_zeroes_serving_gauges_and_sketches() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let server = std::thread::spawn(move || {
        // heavy enough that 48 tokens cannot finish inside a 1 ms deadline,
        // paged so the high-water gauge has something to resurrect
        let mut c = cfg();
        c.d_model = 64;
        c.n_heads = 4;
        c.d_ff = 256;
        c.max_seq = 64;
        let backend = HostBackend::random(c, 0, 2, 6).unwrap();
        let ecfg = EngineConfig {
            paged_kv: Some(rsb::engine::PagedKvCfg {
                page_size: 16,
                n_pages: 8,
            }),
            ..EngineConfig::default()
        };
        let engine = Engine::new(Box::new(backend), ecfg).unwrap();
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", Some(2), Some(ready_tx), 0)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();

    // a deadline eviction populates every gauge the reset must clear
    client
        .send_line(
            "{\"id\": 1, \"prompt\": \"ab ba\", \"max_tokens\": 48, \"deadline_ms\": 1}",
        )
        .unwrap();
    let resp = client.recv().unwrap();
    assert_eq!(resp.str_of("finish").unwrap(), "deadline");
    let snap = client.cmd("metrics").unwrap();
    let engine = snap.req("engine").unwrap();
    assert_eq!(engine.usize_of("deadline_evictions").unwrap(), 1);
    assert!(engine.usize_of("kv_pages_high_water").unwrap() > 0);
    assert!(!engine
        .req("admissions_per_step")
        .unwrap()
        .as_arr()
        .unwrap()
        .is_empty());

    // reset, then verify the pre-PR-7 gauges did NOT survive it
    assert!(client.cmd("reset").unwrap().bool_of("ok").unwrap());
    let snap = client.cmd("metrics").unwrap();
    let engine = snap.req("engine").unwrap();
    assert_eq!(engine.usize_of("deadline_evictions").unwrap(), 0);
    assert_eq!(
        engine.usize_of("kv_pages_high_water").unwrap(),
        0,
        "the pool's pre-reset peak leaked back into the gauge"
    );
    assert!(engine
        .req("admissions_per_step")
        .unwrap()
        .as_arr()
        .unwrap()
        .is_empty());
    // the latency sketches restarted too
    assert_eq!(
        engine
            .req("time_to_first_token_ms")
            .unwrap()
            .usize_of("n")
            .unwrap(),
        0
    );
    // geometry survives: the pool is still 8 pages
    assert_eq!(engine.usize_of("kv_pages_total").unwrap(), 8);

    // the engine still serves after the reset
    let resp = client.request(2, "ab", 2, 0.0).unwrap();
    assert_eq!(resp.str_of("finish").unwrap(), "maxtokens");
    assert_eq!(server.join().unwrap().unwrap(), 2);
}

/// ISSUE 9: `{"cmd":"metrics_prom"}` returns the Prometheus text
/// exposition (with build-info) and completions carry the per-request
/// `timings` attribution object.
#[test]
fn metrics_prom_build_info_and_completion_timings() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let server = std::thread::spawn(move || {
        let backend = HostBackend::random(cfg(), 0, 2, 6).unwrap();
        let engine = Engine::new(Box::new(backend), EngineConfig::default()).unwrap();
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", Some(1), Some(ready_tx), 0)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();

    // the completion carries the lifecycle attribution
    let resp = client.request(1, "ab ba", 4, 0.0).unwrap();
    let timings = resp.req("timings").expect("completion timings");
    assert!(timings.f64_of("total_ms").unwrap() > 0.0);
    assert!(timings.f64_of("ttft_ms").unwrap() > 0.0);
    assert!(timings.f64_of("prefill_ms").unwrap() > 0.0);
    assert!(timings.f64_of("queue_ms").unwrap() >= 0.0);
    assert!(timings.f64_of("decode_ms").unwrap() >= 0.0);
    assert_eq!(timings.f64_of("kv_wait_ms").unwrap(), 0.0, "dense KV never blocks");

    // build_info rides the JSON snapshot
    let snap = client.cmd("metrics").unwrap();
    let bi = snap.req("build_info").unwrap();
    assert_eq!(bi.str_of("backend").unwrap(), "host");
    assert_eq!(bi.str_of("quant").unwrap(), "f32");
    assert!(!bi.str_of("version").unwrap().is_empty());
    assert!(!bi.str_of("simd").unwrap().is_empty());
    assert!(bi.f64_of("uptime_seconds").unwrap() >= 0.0);

    // metrics_prom: exposition body with counters, histograms, build info
    let prom = client.cmd("metrics_prom").unwrap();
    assert!(prom.bool_of("ok").unwrap());
    assert_eq!(
        prom.str_of("content_type").unwrap(),
        "text/plain; version=0.0.4"
    );
    let body = prom.str_of("body").unwrap();
    assert!(body.contains("# TYPE pallas_tokens_generated_total counter"));
    assert!(body.contains("pallas_tokens_generated_total 4\n"));
    assert!(body.contains("pallas_build_info{"));
    assert!(body.contains("# TYPE pallas_request_latency_ms histogram"));
    assert!(body.contains("_bucket{le="));
    assert!(body.contains("pallas_server_served_total 1\n"));
    // every non-comment line is pallas_-prefixed (the scrape contract
    // tools/prom_check.py enforces in CI)
    for line in body.lines() {
        assert!(
            line.is_empty() || line.starts_with('#') || line.starts_with("pallas_"),
            "non-pallas line in exposition: {line:?}"
        );
    }
    assert_eq!(server.join().unwrap().unwrap(), 1);
}
