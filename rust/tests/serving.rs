//! Engine-level serving semantics: continuous vs wave admission, chunked
//! prefill, paged-KV scheduling, deadlines, backpressure, and per-token
//! streaming events. Everything runs on the host backend with greedy
//! sampling so token sequences are exact and comparable across engine
//! configurations.

use rsb::engine::{
    Admission, Completion, Engine, EngineConfig, FinishReason, PagedKvCfg, Request,
};
use rsb::hostexec::HostBackend;
use rsb::runtime::artifact::ModelCfg;

fn cfg() -> ModelCfg {
    ModelCfg {
        size: "t".into(),
        arch: "opt".into(),
        act: "relu".into(),
        stage: 0,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 40,
        max_seq: 20,
        shift: 1.0,
        ffn_act: "relu".into(),
        gated: false,
        parallel_block: false,
        has_bias: true,
    }
}

fn engine(decode_b: usize, ecfg: EngineConfig) -> Engine {
    let be = HostBackend::random(cfg(), 5, decode_b, 6).unwrap();
    Engine::new(Box::new(be), ecfg).unwrap()
}

fn run_to_completion(eng: &mut Engine) -> Vec<Completion> {
    let mut done = Vec::new();
    for _ in 0..10_000 {
        if !eng.has_work() {
            return done;
        }
        done.extend(eng.step().unwrap());
    }
    panic!("engine did not drain in 10k steps");
}

fn tokens_by_id(done: &[Completion]) -> Vec<(u64, Vec<u32>)> {
    let mut v: Vec<(u64, Vec<u32>)> = done.iter().map(|c| (c.id, c.tokens.clone())).collect();
    v.sort_by_key(|(id, _)| *id);
    v
}

const WORKLOAD: [(&[u32], usize); 4] =
    [(&[3, 4], 6), (&[7, 8, 9, 2, 5], 4), (&[1], 8), (&[6, 2, 3], 5)];

fn submit_workload(eng: &mut Engine) {
    for (prompt, max_new) in WORKLOAD {
        eng.submit(prompt.to_vec(), max_new);
    }
}

/// Chunked prefill must be a pure scheduling change: same tokens out as
/// one-shot padded-bucket prefill, request by request.
#[test]
fn chunked_prefill_matches_one_shot_tokens() {
    let mut one_shot = engine(2, EngineConfig::default());
    submit_workload(&mut one_shot);
    let base = tokens_by_id(&run_to_completion(&mut one_shot));

    for chunk in [1, 2, 5] {
        let mut chunked = engine(
            2,
            EngineConfig {
                prefill_chunk: chunk,
                ..EngineConfig::default()
            },
        );
        submit_workload(&mut chunked);
        let got = tokens_by_id(&run_to_completion(&mut chunked));
        assert_eq!(base, got, "chunk={chunk} diverged from one-shot prefill");
    }
}

/// One-shot prefill tail-clamps prompts to the padded bucket; chunked
/// prefill accepts anything up to `max_seq - 1` and feeds it in pieces.
#[test]
fn chunked_prefill_accepts_prompts_longer_than_bucket() {
    let mut eng = engine(
        2,
        EngineConfig {
            prefill_chunk: 4,
            ..EngineConfig::default()
        },
    );
    let prompt: Vec<u32> = (1..=14).collect();
    eng.submit(prompt, 3);
    let done = run_to_completion(&mut eng);
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].prompt_len, 14, "full prompt retained, not clamped to bucket");
    assert_eq!(done[0].tokens.len(), 3);
    assert_eq!(done[0].finish, FinishReason::MaxTokens);
}

/// Paged KV is a storage change, not a model change: the served tokens are
/// exactly the dense engine's, and the page gauges reconcile.
#[test]
fn paged_engine_matches_dense_engine_tokens() {
    let mut dense = engine(2, EngineConfig::default());
    submit_workload(&mut dense);
    let base = tokens_by_id(&run_to_completion(&mut dense));

    let mut paged = engine(
        2,
        EngineConfig {
            paged_kv: Some(PagedKvCfg {
                page_size: 4,
                n_pages: 24,
            }),
            ..EngineConfig::default()
        },
    );
    submit_workload(&mut paged);
    let got = tokens_by_id(&run_to_completion(&mut paged));
    assert_eq!(base, got, "paged KV changed served tokens");
    assert_eq!(paged.metrics.kv_pages_total, 24);
    assert_eq!(paged.metrics.kv_pages_in_use, 0, "all pages returned after drain");
    assert!(paged.metrics.kv_pages_high_water > 0);
}

/// Page exhaustion stalls admission (FIFO, no deadlock thanks to
/// worst-case reservation); a request that cannot fit the whole pool even
/// alone is rejected up front as `ContextFull`.
#[test]
fn paged_admission_blocks_until_pages_free_and_rejects_oversize() {
    let mut eng = engine(
        2,
        EngineConfig {
            paged_kv: Some(PagedKvCfg {
                page_size: 4,
                n_pages: 2,
            }),
            ..EngineConfig::default()
        },
    );
    let a = eng.submit(vec![3, 4], 2); // needs 1 page
    let big = eng.submit(vec![5, 6, 7], 8); // needs 3 pages > pool: impossible
    let b = eng.submit(vec![8, 9], 2); // needs 1 page
    let c = eng.submit(vec![2, 3], 5); // needs 2 pages: waits for a drain
    let done = run_to_completion(&mut eng);
    assert_eq!(done.len(), 4);
    for comp in &done {
        if comp.id == big {
            assert_eq!(comp.finish, FinishReason::ContextFull);
            assert!(comp.tokens.is_empty());
        } else {
            assert_eq!(comp.finish, FinishReason::MaxTokens, "request {} stalled", comp.id);
        }
    }
    let n = |id| done.iter().find(|c| c.id == id).unwrap().tokens.len();
    assert_eq!((n(a), n(b), n(c)), (2, 2, 5));
    assert_eq!(eng.metrics.kv_pages_in_use, 0);
    assert_eq!(eng.metrics.kv_pages_high_water, 2, "pool saturated at some point");
}

/// Wave admission (the fixed-batch baseline) only refills when every slot
/// has drained: the admissions-per-step histogram shows full waves and no
/// single-slot backfill, unlike continuous batching.
#[test]
fn waves_admission_drains_before_refilling() {
    let mut eng = engine(
        2,
        EngineConfig {
            admission: Admission::Waves,
            ..EngineConfig::default()
        },
    );
    for max_new in [2, 6, 2, 2] {
        eng.submit(vec![3], max_new);
    }
    let done = run_to_completion(&mut eng);
    assert_eq!(done.len(), 4);
    let hist = &eng.metrics.admissions_per_step;
    assert_eq!(hist.get(2).copied().unwrap_or(0), 2, "two full waves of 2");
    assert_eq!(hist.get(1).copied().unwrap_or(0), 0, "no continuous backfill under waves");

    let mut cont = engine(2, EngineConfig::default());
    for max_new in [2, 6, 2, 2] {
        cont.submit(vec![3], max_new);
    }
    run_to_completion(&mut cont);
    assert!(
        cont.metrics.admissions_per_step.get(1).copied().unwrap_or(0) >= 1,
        "continuous admission backfills freed slots mid-wave"
    );
}

/// `step_ext` token events reconstruct every completion exactly: one event
/// per generated token, in order, with contiguous indices.
#[test]
fn token_events_stream_matches_completions() {
    let mut eng = engine(2, EngineConfig::default());
    submit_workload(&mut eng);
    let mut events: Vec<(u64, Vec<u32>)> = Vec::new();
    let mut done = Vec::new();
    for _ in 0..10_000 {
        if !eng.has_work() {
            break;
        }
        let out = eng.step_ext().unwrap();
        for ev in &out.emitted {
            let row = match events.iter_mut().find(|(id, _)| *id == ev.id) {
                Some(r) => r,
                None => {
                    events.push((ev.id, Vec::new()));
                    events.last_mut().unwrap()
                }
            };
            assert_eq!(ev.index, row.1.len(), "event indices must be contiguous");
            row.1.push(ev.token);
        }
        done.extend(out.done);
    }
    assert_eq!(done.len(), WORKLOAD.len());
    events.sort_by_key(|(id, _)| *id);
    assert_eq!(events, tokens_by_id(&done), "streamed events != completion tokens");
}

/// Deadlines evict both queued requests (never started) and running ones
/// (partial output), each finishing as `Deadline`.
#[test]
fn deadlines_evict_queued_and_running_requests() {
    let mut eng = engine(1, EngineConfig::default());
    let slow = eng
        .try_submit(Request::new(0, vec![3, 4], 15).with_deadline_ms(5))
        .unwrap();
    let queued = eng
        .try_submit(Request::new(0, vec![5], 5).with_deadline_ms(0))
        .unwrap();
    let first = eng.step().unwrap();
    assert_eq!(first.len(), 1, "expired queued request swept before admission");
    assert_eq!(first[0].id, queued);
    assert_eq!(first[0].finish, FinishReason::Deadline);
    assert!(first[0].tokens.is_empty());

    std::thread::sleep(std::time::Duration::from_millis(10));
    let done = run_to_completion(&mut eng);
    let slow_c = done.iter().find(|c| c.id == slow).unwrap();
    assert_eq!(slow_c.finish, FinishReason::Deadline);
    assert!(!slow_c.tokens.is_empty(), "ran before the deadline hit");
    assert!(slow_c.tokens.len() < 15);
    assert_eq!(eng.metrics.deadline_evictions, 2);
}

/// `try_submit` sheds load once the waiting queue hits `queue_cap`;
/// accepted requests are unaffected.
#[test]
fn try_submit_enforces_queue_cap() {
    let mut eng = engine(
        2,
        EngineConfig {
            queue_cap: 2,
            ..EngineConfig::default()
        },
    );
    assert!(eng.try_submit(Request::new(0, vec![3], 2)).is_some());
    assert!(eng.try_submit(Request::new(0, vec![4], 2)).is_some());
    assert!(eng.try_submit(Request::new(0, vec![5], 2)).is_none(), "third must be shed");
    assert_eq!(eng.metrics.backpressure_rejections, 1);
    let done = run_to_completion(&mut eng);
    assert_eq!(done.len(), 2, "accepted requests still complete");
}
