//! End-to-end speculative decoding on the host execution backend — no
//! PJRT, no artifacts, runs under `cargo test --no-default-features` (the
//! CI host gate). The ISSUE 5 acceptance surface:
//!
//! - the committed golden specdec fixtures: a dense-verify run over the
//!   decode fixture (`host_tiny.ckpt` target + `host_tiny_draft.ckpt`
//!   draft) and a sparse-verify run over the engineered-persistence target
//!   (`specdec_hot.ckpt`), both generated and cross-validated against the
//!   L2 JAX reference by `tools/make_host_fixture.py` — token IDs, round /
//!   accepted / bonus counts and the `s_agg_gamma` schedule are pinned;
//! - greedy equivalence: speculative decoding is token-identical to
//!   target-only greedy decoding under `VerifyMask::Dense` (structural:
//!   every committed token is a target argmax) and under
//!   `VerifyMask::Aggregated` at recall-safe windows, across
//!   opt/llama/falcon;
//! - stochastic acceptance sanity under a seeded `Rng`;
//! - `SpecStats` edge cases: γ=1, zero-round generations and prompts
//!   shorter than the window stay finite and clamped.

use rsb::engine::{AcceptMode, Engine, EngineConfig, SpecDecoder, VerifyMask};
use rsb::hostexec::{HostBackend, HostParams};
use rsb::runtime::artifact::ModelCfg;
use rsb::runtime::ExecBackend;

/// Mirror of the fixture config in tools/make_host_fixture.py (CFG) — keep
/// in sync with the generator and rust/tests/hostexec.rs.
fn fixture_cfg() -> ModelCfg {
    ModelCfg {
        size: "fixture".into(),
        arch: "opt".into(),
        act: "relu".into(),
        stage: 0,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        vocab: 48,
        max_seq: 24,
        shift: 1.0,
        ffn_act: "relu".into(),
        gated: false,
        parallel_block: false,
        has_bias: true,
    }
}

/// Mirror of CFG_DRAFT in tools/make_host_fixture.py — keep in sync.
fn draft_fixture_cfg() -> ModelCfg {
    ModelCfg {
        size: "draftfix".into(),
        arch: "opt".into(),
        act: "relu".into(),
        stage: 0,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_ff: 32,
        vocab: 48,
        max_seq: 24,
        shift: 1.0,
        ffn_act: "relu".into(),
        gated: false,
        parallel_block: false,
        has_bias: true,
    }
}

fn fixture_backend(file: &str, cfg: ModelCfg) -> HostBackend {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(file);
    HostBackend::from_checkpoint(cfg, &path, 1, 8).unwrap()
}

const FIXTURE_PROMPT: [u32; 5] = [3, 1, 4, 1, 5];

/// Golden fixture, dense verification: greedy specdec over the committed
/// target/draft pair must commit exactly the target-only greedy golden
/// tokens (the same IDs hostexec.rs pins for plain decode), with the round
/// schedule the generator cross-validated against the L2 JAX reference.
#[test]
fn golden_dense_specdec_matches_target_greedy_and_pins_counters() {
    let target = fixture_backend("host_tiny.ckpt", fixture_cfg());
    let draft = fixture_backend("host_tiny_draft.ckpt", draft_fixture_cfg());
    let mut dec = SpecDecoder::new(
        Box::new(target),
        Box::new(draft),
        2,
        AcceptMode::Greedy,
        VerifyMask::Dense,
        0,
    )
    .unwrap();
    let (tokens, stats) = dec.generate(&FIXTURE_PROMPT, 10).unwrap();
    assert_eq!(
        tokens,
        vec![27, 1, 32, 32, 32, 28, 28, 39, 39, 39],
        "golden dense specdec drifted from the L2 reference"
    );
    assert_eq!(stats.rounds, 5, "round schedule drifted");
    assert_eq!(stats.drafted, 10);
    assert_eq!(stats.accepted, 5, "acceptance schedule drifted");
    assert_eq!(stats.bonus, 5, "every round commits a bonus/corrected token");
    assert!((stats.acceptance_rate() - 0.5).abs() < 1e-12);
    assert!((stats.tokens_per_round() - 2.0).abs() < 1e-12);
    // dense verification: the window is never consulted
    assert_eq!(stats.s_agg_gamma, 0.0);
    // measured per-token liveness of the verify passes (generator: 0.5484;
    // a liveness bit sitting on the ReLU threshold could flip across f32
    // implementations, so this one is pinned with slack)
    assert!(
        (stats.s_token - 0.5484).abs() < 0.05,
        "s_token {} drifted from the generator's 0.5484",
        stats.s_token
    );
    assert!(stats.c_measured.is_finite() && stats.c_measured >= 0.0);
    assert!(stats.verify_secs > 0.0 && stats.draft_secs > 0.0);

    // and the engine's target-only greedy decode agrees token for token
    let solo = fixture_backend("host_tiny.ckpt", fixture_cfg());
    let mut e = Engine::new(Box::new(solo), EngineConfig::default()).unwrap();
    e.submit(FIXTURE_PROMPT.to_vec(), 10);
    assert_eq!(e.run_to_completion().unwrap().remove(0).tokens, tokens);
}

/// Golden fixture, sparse verification: the engineered-persistence target
/// (half of every layer's neurons always fire, half never — paper §5.1's
/// reuse mechanism distilled) makes the aggregated window recall-safe by
/// construction, so `VerifyMask::Aggregated` is token-identical to dense
/// while every verify pass really runs at density 0.5. Tokens, counters
/// and the exact s_agg/s_token values are pinned.
#[test]
fn golden_sparse_specdec_hot_fixture_is_pinned() {
    let mk = || {
        SpecDecoder::new(
            Box::new(fixture_backend("specdec_hot.ckpt", fixture_cfg())),
            Box::new(fixture_backend("host_tiny_draft.ckpt", draft_fixture_cfg())),
            3,
            AcceptMode::Greedy,
            VerifyMask::Aggregated { window: 16 },
            0,
        )
        .unwrap()
    };
    let (tokens, stats) = mk().generate(&FIXTURE_PROMPT, 12).unwrap();
    assert_eq!(
        tokens,
        vec![4; 12],
        "golden sparse specdec drifted from the L2 reference"
    );
    assert_eq!(stats.rounds, 5, "round schedule drifted");
    assert_eq!(stats.drafted, 15);
    assert_eq!(stats.accepted, 6, "acceptance schedule drifted");
    assert_eq!(stats.bonus, 5);
    // the engineered hot set is exactly half of every layer: the window
    // union (and every per-token mask) has density 0.5 — EXACTLY, which is
    // what makes this fixture pinnable across f32 implementations (min
    // |preact| margin 0.957 per the generator)
    assert!(
        (stats.s_agg_gamma - 0.5).abs() < 1e-12,
        "s_agg {} != engineered 0.5",
        stats.s_agg_gamma
    );
    assert!(
        (stats.s_token - 0.5).abs() < 1e-12,
        "s_token {} != engineered 0.5",
        stats.s_token
    );

    // recall-safe window: sparse verification must not change a single
    // token vs dense verification on the same pair
    let mut dense = mk();
    dense.mask_mode = VerifyMask::Dense;
    let (dense_tokens, dense_stats) = dense.generate(&FIXTURE_PROMPT, 12).unwrap();
    assert_eq!(tokens, dense_tokens, "aggregated verify changed tokens");
    assert_eq!(dense_stats.accepted, stats.accepted);
    assert_eq!(dense_stats.rounds, stats.rounds);
    assert_eq!(dense_stats.s_agg_gamma, 0.0);
}

fn tiny_cfg(arch: &str) -> ModelCfg {
    let act = if arch == "llama" { "silu" } else { "relu" };
    ModelCfg {
        size: "t".into(),
        arch: arch.into(),
        act: act.into(),
        stage: 0,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 40,
        max_seq: 24,
        shift: 1.0,
        ffn_act: act.into(),
        gated: arch == "llama",
        parallel_block: arch == "falcon",
        has_bias: arch == "opt",
    }
}

fn tiny_draft_cfg(arch: &str) -> ModelCfg {
    let mut c = tiny_cfg(arch);
    c.size = "td".into();
    c.n_layers = 1;
    c.d_ff = 16;
    c
}

/// Target-only greedy reference through the serving engine (same backend
/// seed ⇒ same weights).
fn engine_greedy(cfg: ModelCfg, seed: u64, prompt: &[u32], n: usize) -> Vec<u32> {
    let backend = HostBackend::random(cfg, seed, 1, 6).unwrap();
    let mut e = Engine::new(Box::new(backend), EngineConfig::default()).unwrap();
    e.submit(prompt.to_vec(), n);
    e.run_to_completion().unwrap().remove(0).tokens
}

/// Structural equivalence: under dense verification, greedy speculative
/// decoding commits exactly the target's greedy stream — whatever the
/// draft proposes — on every architecture and γ.
#[test]
fn dense_specdec_is_token_identical_to_target_greedy() {
    let prompt: Vec<u32> = vec![5, 9, 13, 21];
    let n = 12usize;
    for arch in ["opt", "llama", "falcon"] {
        let want = engine_greedy(tiny_cfg(arch), 42, &prompt, n);
        assert_eq!(want.len(), n);
        for gamma in [1usize, 3] {
            let target = HostBackend::random(tiny_cfg(arch), 42, 1, 6).unwrap();
            let draft = HostBackend::random(tiny_draft_cfg(arch), 7, 1, 6).unwrap();
            let mut dec = SpecDecoder::new(
                Box::new(target),
                Box::new(draft),
                gamma,
                AcceptMode::Greedy,
                VerifyMask::Dense,
                0,
            )
            .unwrap();
            let (tokens, stats) = dec.generate(&prompt, n).unwrap();
            assert_eq!(tokens, want, "{arch}/gamma={gamma}: specdec diverged");
            assert_eq!(stats.drafted, stats.rounds * gamma, "{arch}");
            assert!(stats.accepted <= stats.drafted);
            assert_eq!(stats.bonus, stats.rounds, "one bonus/corrected per round");
            assert!(stats.tokens_per_round() >= 1.0, "{arch}");
            let a = stats.acceptance_rate();
            assert!((0.0..=1.0).contains(&a), "{arch}: alpha {a}");
        }
    }
}

/// Aggregated verification at recall-safe windows is token-identical to
/// target-only greedy, across architectures. Recall safety is engineered
/// per arch (all three are deterministic constructions, not luck):
/// - opt: `b_up = ±2.5` splits neurons into always-fire / never-fire
///   halves (|w·h| ≪ 2.5), so every mask is exactly the hot half;
/// - llama: SwiGLU liveness is gated by silu, which is nonzero for every
///   nonzero preactivation — masks are all-ones and the union is dense;
/// - falcon: ln1 bias +5 makes the shared norm output positive, and
///   sign-coherent up-projection rows (hot ⇒ |w|, cold ⇒ -|w|) make the
///   preactivation sign per-neuron constant.
#[test]
fn aggregated_specdec_recall_safe_windows_match_target_greedy() {
    let prompt: Vec<u32> = vec![5, 9, 13, 21];
    let n = 12usize;
    let gamma = 3usize;
    for arch in ["opt", "llama", "falcon"] {
        let cfg = tiny_cfg(arch);
        let (d, f) = (cfg.d_model, cfg.d_ff);
        let engineer = |mut params: HostParams| -> HostParams {
            match arch {
                "opt" => {
                    for lw in &mut params.layers {
                        for j in 0..f {
                            lw.ffn.w.b_up[j] = if j < f / 2 { 2.5 } else { -2.5 };
                        }
                    }
                }
                "falcon" => {
                    for lw in &mut params.layers {
                        if let Some(b) = lw.ln1_bias.as_mut() {
                            b.iter_mut().for_each(|x| *x = 5.0);
                        }
                        for j in 0..f {
                            let row = &mut lw.ffn.w.w_up_t[j * d..(j + 1) * d];
                            for w in row.iter_mut() {
                                *w = if j < f / 2 { w.abs() } else { -w.abs() };
                            }
                        }
                    }
                }
                _ => {} // llama: silu liveness is structurally dense
            }
            params
        };
        let mk_target = || {
            let params = engineer(HostParams::random(&cfg, 42).unwrap());
            HostBackend::new(cfg.clone(), params, 1, 6).unwrap()
        };
        // target-only greedy reference over the engineered weights
        let mut e = Engine::new(Box::new(mk_target()), EngineConfig::default()).unwrap();
        e.submit(prompt.clone(), n);
        let want = e.run_to_completion().unwrap().remove(0).tokens;

        let mk_dec = |mask| {
            SpecDecoder::new(
                Box::new(mk_target()),
                Box::new(HostBackend::random(tiny_draft_cfg(arch), 7, 1, 6).unwrap()),
                gamma,
                AcceptMode::Greedy,
                mask,
                0,
            )
            .unwrap()
        };
        let (sparse, stats) = mk_dec(VerifyMask::Aggregated { window: 64 })
            .generate(&prompt, n)
            .unwrap();
        assert_eq!(
            sparse, want,
            "{arch}: recall-safe aggregated verify changed tokens"
        );
        let (dense, _) = mk_dec(VerifyMask::Dense).generate(&prompt, n).unwrap();
        assert_eq!(sparse, dense, "{arch}: aggregated != dense");
        match arch {
            // engineered half-split: union density exactly 0.5
            "opt" | "falcon" => assert!(
                (stats.s_agg_gamma - 0.5).abs() < 1e-12,
                "{arch}: s_agg {} != 0.5",
                stats.s_agg_gamma
            ),
            // silu liveness is dense: no aggregated sparsity to exploit
            _ => assert!(
                stats.s_agg_gamma < 0.01,
                "llama: s_agg {} should be ~0",
                stats.s_agg_gamma
            ),
        }
    }
}

/// Stochastic acceptance: with draft == target (identical weights) the
/// ratio p/q is exactly 1, so every draft is accepted — and the whole run
/// is deterministic in the seed.
#[test]
fn stochastic_accepts_everything_when_draft_equals_target() {
    let prompt: Vec<u32> = vec![2, 4, 8];
    let mk = |seed: u64| {
        SpecDecoder::new(
            Box::new(HostBackend::random(tiny_cfg("opt"), 42, 1, 6).unwrap()),
            Box::new(HostBackend::random(tiny_cfg("opt"), 42, 1, 6).unwrap()),
            3,
            AcceptMode::Stochastic,
            VerifyMask::Dense,
            seed,
        )
        .unwrap()
    };
    let (tokens, stats) = mk(9).generate(&prompt, 12).unwrap();
    assert_eq!(tokens.len(), 12);
    assert_eq!(
        stats.accepted, stats.drafted,
        "identical models must accept every draft (p/q == 1)"
    );
    assert!((stats.acceptance_rate() - 1.0).abs() < 1e-12);
    assert_eq!(stats.bonus, stats.rounds);
    // seeded determinism: the same seed reproduces the run exactly
    let (again, s2) = mk(9).generate(&prompt, 12).unwrap();
    assert_eq!(tokens, again);
    assert_eq!(s2.accepted, stats.accepted);
}

/// Stochastic with a *different* draft: acceptance is a real rate in
/// [0, 1], the token stream is valid, and repeated runs with one decoder
/// are reproducible (generate resets seeded state).
#[test]
fn stochastic_different_draft_is_sane_and_reproducible() {
    let mut dec = SpecDecoder::new(
        Box::new(HostBackend::random(tiny_cfg("opt"), 42, 1, 6).unwrap()),
        Box::new(HostBackend::random(tiny_draft_cfg("opt"), 7, 1, 6).unwrap()),
        2,
        AcceptMode::Stochastic,
        VerifyMask::Dense,
        5,
    )
    .unwrap();
    let prompt: Vec<u32> = vec![5, 9, 13];
    let (tokens, stats) = dec.generate(&prompt, 10).unwrap();
    assert_eq!(tokens.len(), 10);
    let vocab = dec.target().config().vocab as u32;
    assert!(tokens.iter().all(|&t| t < vocab));
    let a = stats.acceptance_rate();
    assert!((0.0..=1.0).contains(&a));
    assert_eq!(stats.drafted, stats.rounds * 2);
    // the decoder resets per generate: a second call is bit-identical
    let (again, s2) = dec.generate(&prompt, 10).unwrap();
    assert_eq!(tokens, again, "generate must reset seeded state");
    assert_eq!(stats.accepted, s2.accepted);
}

/// SpecStats edge cases (the γ=1 / short-prompt / zero-round NaN traps):
/// everything stays finite and in range.
#[test]
fn spec_stats_edge_cases_stay_finite_and_clamped() {
    let mk = |gamma, mask| {
        SpecDecoder::new(
            Box::new(HostBackend::random(tiny_cfg("opt"), 42, 1, 6).unwrap()),
            Box::new(HostBackend::random(tiny_draft_cfg("opt"), 7, 1, 6).unwrap()),
            gamma,
            AcceptMode::Greedy,
            mask,
            0,
        )
        .unwrap()
    };
    // γ=1 with a window far longer than the prompt (and the whole run)
    let (tokens, stats) =
        mk(1, VerifyMask::Aggregated { window: 1000 }).generate(&[2], 8).unwrap();
    assert_eq!(tokens.len(), 8);
    for v in [
        stats.c_measured,
        stats.s_agg_gamma,
        stats.s_token,
        stats.acceptance_rate(),
        stats.tokens_per_round(),
        stats.verify_secs_per_round(),
    ] {
        assert!(v.is_finite(), "non-finite stat {v}");
    }
    assert!((0.0..=1.0).contains(&stats.s_agg_gamma));
    assert!((0.0..=1.0).contains(&stats.s_token));
    assert!(stats.c_measured >= 0.0);

    // zero rounds: n_tokens <= 1 never enters the loop
    let (one, s1) = mk(1, VerifyMask::Aggregated { window: 4 }).generate(&[2, 3], 1).unwrap();
    assert_eq!(one.len(), 1);
    assert_eq!(s1.rounds, 0);
    assert_eq!(s1.c_measured, 0.0);
    assert_eq!(s1.s_agg_gamma, 0.0);
    assert_eq!(s1.s_token, 0.0);
    assert_eq!(s1.tokens_per_round(), 0.0);
    assert_eq!(s1.verify_secs_per_round(), 0.0);
    let (zero, s0) = mk(2, VerifyMask::Dense).generate(&[2, 3], 0).unwrap();
    assert!(zero.is_empty());
    assert_eq!(s0.rounds, 0);

    // the Random control mode also runs clean end-to-end
    let (r, sr) = mk(2, VerifyMask::Random { window: 8 }).generate(&[2, 3, 5], 8).unwrap();
    assert_eq!(r.len(), 8);
    assert!((0.0..=1.0).contains(&sr.s_agg_gamma));
}

/// Constructor validation: vocab mismatch, γ bounds, verify bucket and
/// batch-width requirements all fail early with clear errors.
#[test]
fn spec_decoder_rejects_bad_pairs() {
    let t = || Box::new(HostBackend::random(tiny_cfg("opt"), 42, 1, 6).unwrap());
    let d = || Box::new(HostBackend::random(tiny_draft_cfg("opt"), 7, 1, 6).unwrap());
    // gamma 0 and gamma beyond the verify bucket (default min(8, max_seq))
    assert!(SpecDecoder::new(t(), d(), 0, AcceptMode::Greedy, VerifyMask::Dense, 0).is_err());
    assert!(SpecDecoder::new(t(), d(), 8, AcceptMode::Greedy, VerifyMask::Dense, 0).is_err());
    assert!(SpecDecoder::new(t(), d(), 7, AcceptMode::Greedy, VerifyMask::Dense, 0).is_ok());
    // vocab mismatch
    let mut other = tiny_draft_cfg("opt");
    other.vocab = 44;
    let mismatched = Box::new(HostBackend::random(other, 7, 1, 6).unwrap());
    assert!(
        SpecDecoder::new(t(), mismatched, 2, AcceptMode::Greedy, VerifyMask::Dense, 0).is_err()
    );
    // non-B=1 sides are refused
    let wide = Box::new(HostBackend::random(tiny_cfg("opt"), 42, 2, 6).unwrap());
    assert!(SpecDecoder::new(wide, d(), 2, AcceptMode::Greedy, VerifyMask::Dense, 0).is_err());
}
