//! Property-based tests over the coordinator substrates (no `proptest`
//! offline — `mod prop_rt` is a small seeded-case runner with failure
//! reporting; cases are deterministic so failures reproduce exactly).

use rsb::engine::kv::{KvBatch, SlotManager};
use rsb::engine::ExecBackend;
use rsb::engine::request::SamplingParams;
use rsb::engine::sampler::{argmax, log_softmax, sample, softmax};
use rsb::jsonx::{self, Value};
use rsb::obs::layer_live_counts;
use rsb::predictor::{HotSet, NeuronPolicy, SlotPredictor};
use rsb::runtime::checkpoint;
use rsb::runtime::tensor::Tensor;
use rsb::runtime::BatchMask;
use rsb::sparse::{dense_ffn_matvec, sparse_ffn_matvec, FfnWeights};
use rsb::sparsity::{mask_accuracy, AggregatedTracker, ReusePolicy, ReuseStrategy};
use rsb::tokenizer::Bpe;
use rsb::util::rng::Rng;

mod prop_rt {
    use super::Rng;

    /// Run `f` over `n` seeded cases; panic with the failing seed.
    pub fn check(name: &str, n: u64, f: impl Fn(&mut Rng)) {
        for seed in 0..n {
            let mut rng = Rng::new(0xBEEF ^ seed);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f(&mut rng)
            }));
            if let Err(e) = result {
                eprintln!("property `{name}` failed at seed {seed}");
                std::panic::resume_unwind(e);
            }
        }
    }
}

use prop_rt::check;

#[test]
fn prop_slot_manager_never_double_owns() {
    check("slot_manager", 50, |rng| {
        let cap = rng.range(1, 8);
        let mut sm = SlotManager::new(cap);
        let mut owned: std::collections::HashMap<usize, u64> = Default::default();
        for step in 0..200u64 {
            if rng.chance(0.55) {
                if let Some(slot) = sm.alloc(step) {
                    assert!(!owned.contains_key(&slot), "slot {slot} double-allocated");
                    owned.insert(slot, step);
                }
            } else if let Some((&slot, _)) = owned.iter().next() {
                let id = owned.remove(&slot).unwrap();
                assert_eq!(sm.release(slot).unwrap(), id);
                assert!(sm.release(slot).is_err(), "double free accepted");
            }
            assert_eq!(sm.capacity() - sm.free_count(), owned.len());
            for (&slot, &id) in &owned {
                assert_eq!(sm.owner_of(slot), Some(id));
            }
        }
    });
}

#[test]
fn prop_kv_pack_extract_roundtrip_random() {
    check("kv_roundtrip", 25, |rng| {
        let (l, b, h, t, hd) = (
            rng.range(1, 3),
            rng.range(1, 5),
            rng.range(1, 3),
            rng.range(1, 6),
            rng.range(1, 4),
        );
        let mut kv = KvBatch::new(&[l, 2, b, h, t, hd]).unwrap();
        // pack random rows into random slots; extraction must return them
        let mut expected: Vec<Option<Tensor>> = vec![None; b];
        for _ in 0..b * 2 {
            let slot = rng.below(b);
            let n = l * 2 * h * t * hd;
            let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let row = Tensor::f32(vec![l, 2, 1, h, t, hd], data).unwrap();
            kv.pack_row(slot, &row).unwrap();
            expected[slot] = Some(row);
        }
        for (slot, want) in expected.iter().enumerate() {
            if let Some(w) = want {
                assert_eq!(&kv.extract_row(slot).unwrap(), w);
            }
        }
        // whole-tensor roundtrip
        let t_all = kv.to_tensor();
        kv.update_from(&t_all).unwrap();
        assert_eq!(kv.to_tensor(), t_all);
    });
}

#[test]
fn prop_aggregated_tracker_monotone_and_consistent() {
    check("tracker_monotone", 25, |rng| {
        let (l, b, f) = (rng.range(1, 4), rng.range(1, 3), rng.range(4, 40));
        let mut tr = AggregatedTracker::new(l, f);
        let row = rng.below(b);
        for _ in 0..30 {
            let data: Vec<f32> = (0..l * b * f)
                .map(|_| if rng.chance(0.15) { 1.0 } else { 0.0 })
                .collect();
            let mask = Tensor::f32(vec![l, b, f], data).unwrap();
            tr.push_mask(&mask, row).unwrap();
        }
        for w in tr.curve.windows(2) {
            assert!(w[1] <= w[0] + 1e-12, "curve increased");
        }
        for lc in &tr.layer_curves {
            for w in lc.windows(2) {
                assert!(w[1] <= w[0] + 1e-12);
            }
        }
        // used_mask density == 1 - final aggregated sparsity (per layer mean)
        let m = tr.used_mask();
        let used_frac = m.as_f32().unwrap().iter().filter(|&&x| x != 0.0).count() as f64
            / (l * f) as f64;
        assert!((used_frac - (1.0 - tr.aggregated_sparsity())).abs() < 1e-9);
        // observed aggregated sparsity >= what random baseline predicts is
        // NOT guaranteed pointwise for random masks, but the curve must
        // stay within [0, 1]
        assert!(tr.aggregated_sparsity() >= 0.0 && tr.aggregated_sparsity() <= 1.0);
    });
}

#[test]
fn prop_reuse_policy_masks_structurally_sound() {
    check("reuse_policy", 30, |rng| {
        let (l, f) = (rng.range(1, 3), rng.range(8, 40));
        let gamma = rng.range(1, 6);
        let warmup = rng.range(1, 5);
        let strategy = *rng.choose(&[
            ReuseStrategy::None,
            ReuseStrategy::Aggregated,
            ReuseStrategy::Random,
        ]);
        let mut p = ReusePolicy::new(strategy, gamma, warmup, l, f, 3);
        let mut live_sets: Vec<Vec<usize>> = Vec::new();
        for step in 0..40 {
            let mask = p.current_mask();
            let md = mask.as_f32().unwrap();
            assert_eq!(mask.shape, vec![l, f]);
            assert!(md.iter().all(|&x| x == 0.0 || x == 1.0));
            if !p.is_reusing() {
                assert!(md.iter().all(|&x| x == 1.0), "collect phase must be dense");
            }
            // feed a random ffn_mask observation
            let live: Vec<usize> = (0..f).filter(|_| rng.chance(0.3)).collect();
            let mut data = vec![0.0f32; l * f];
            for li in 0..l {
                for &fi in &live {
                    data[li * f + fi] = 1.0;
                }
            }
            live_sets.push(live);
            let obs = Tensor::f32(vec![l, 1, f], data).unwrap();
            p.observe(&obs, 0).unwrap();
            let _ = step;
        }
    });
}

/// ISSUE 1 satellite: the sparse FFN fast path computed over ANY superset
/// of the ReLU-active neuron set is bit-identical to the dense FFN, for
/// random weights, inputs and random extra predicted neurons.
#[test]
fn prop_sparse_ffn_matvec_equals_dense_on_active_set() {
    check("sparse_ffn_matvec", 30, |rng| {
        let f = rng.range(8, 96);
        let d = rng.range(4, 32);
        let w = FfnWeights::random(f, d, rng.next_u64());
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let active = w.live_set(&x);
        let mut dense = vec![0.0f32; d];
        dense_ffn_matvec(&w, &x, &mut dense);

        // exact active set
        let mut y = vec![0.0f32; d];
        sparse_ffn_matvec(&w, &x, &active, &mut y);
        assert_eq!(dense, y, "exact active set diverged");

        // random superset (a predictor mask with false alarms)
        let active_set: std::collections::HashSet<u32> = active.iter().cloned().collect();
        let superset: Vec<u32> = (0..f as u32)
            .filter(|j| active_set.contains(j) || rng.chance(0.4))
            .collect();
        sparse_ffn_matvec(&w, &x, &superset, &mut y);
        assert_eq!(dense, y, "superset (false alarms) diverged");

        // full mask == dense
        let all: Vec<u32> = (0..f as u32).collect();
        sparse_ffn_matvec(&w, &x, &all, &mut y);
        assert_eq!(dense, y, "full live list diverged");
    });
}

/// HotSet invariants: the union of the last k masks contains every mask it
/// was built from, counts match the ring contents, and top_p(1.0) is
/// exactly the window union.
#[test]
fn prop_hotset_union_and_counts_consistent() {
    check("hotset", 30, |rng| {
        let l = rng.range(1, 3);
        let f = rng.range(8, 64);
        let window = rng.range(1, 6);
        let mut hs = HotSet::new(l, f, window);
        let mut history: Vec<Vec<bool>> = Vec::new();
        for _ in 0..20 {
            let bits: Vec<bool> = (0..l * f).map(|_| rng.chance(0.2)).collect();
            hs.push_bits(bits.clone()).unwrap();
            history.push(bits);
            let k = rng.range(1, window + 1);
            let union = hs.union_of_last(k);
            let in_ring = history.len().min(window);
            for recent in history.iter().rev().take(k.min(in_ring)) {
                for (i, &b) in recent.iter().enumerate() {
                    if b {
                        assert!(union[i], "union lost a recent live neuron");
                    }
                }
            }
            // counts == occurrences over the in-window masks
            for li in 0..l {
                for fi in 0..f {
                    let want = history
                        .iter()
                        .rev()
                        .take(window)
                        .filter(|m| m[li * f + fi])
                        .count() as u32;
                    assert_eq!(hs.count(li, fi), want);
                }
            }
            // budget 1.0 covers everything that fired in-window
            assert_eq!(hs.top_p(1.0), hs.union_of_last(window));
            // predictions are supersets as the budget grows
            let lo = hs.top_p(0.3);
            let hi = hs.top_p(0.9);
            for (a, b) in lo.iter().zip(&hi) {
                assert!(!a || *b, "smaller budget predicted outside larger");
            }
        }
    });
}

/// Slot predictor safety: at recall floor 1.0 (shadow mode) it never asks
/// for a sparse step, whatever the stream does; below 1.0 it only enforces
/// once its shadow recall estimate clears the floor.
#[test]
fn prop_slot_predictor_floor_gates_enforcement() {
    check("slot_predictor", 25, |rng| {
        let f = rng.range(8, 32);
        let window = rng.range(1, 5);
        let union_k = rng.range(1, window + 1);
        let policy = NeuronPolicy::Reuse { window, union_k };
        let floor = *rng.choose(&[0.0, 0.5, 0.9, 1.0]);
        let mut p = SlotPredictor::new(policy, floor, 1, f).unwrap();
        // the engine mirrors the hotset: shadow scores must match a hand
        // computation of union-of-last-k vs the observation
        let mut mirror = HotSet::new(1, f, window);
        for _ in 0..40 {
            let proposal = p.propose().map(|b| b.to_vec());
            if floor >= 1.0 {
                assert!(proposal.is_none(), "shadow mode proposed a sparse step");
            }
            if proposal.is_some() {
                assert!(
                    p.recall_estimate().map_or(false, |est| est >= floor),
                    "enforced below the recall floor"
                );
                // the enforced mask is exactly the mirrored hotset union
                assert_eq!(proposal.as_deref().unwrap(), mirror.union_of_last(union_k));
            }
            let bits: Vec<bool> = (0..f).map(|_| rng.chance(0.3)).collect();
            let obs = Tensor::mask_from_bits(vec![1, 1, f], &bits).unwrap();
            let enforced = proposal.is_some();
            let acc = p.observe(&obs, 0, !enforced).unwrap();
            if enforced {
                assert!(acc.is_none(), "post-gate observation must not be scored");
            } else if let Some(a) = &acc {
                let pred = mirror.union_of_last(union_k);
                assert_eq!(*a, mask_accuracy(&pred, &bits));
            }
            mirror.push_bits(bits).unwrap();
        }
    });
}

#[test]
fn prop_sampler_topk_and_greedy() {
    check("sampler", 40, |rng| {
        let v = rng.range(4, 64);
        let logits: Vec<f32> = (0..v).map(|_| rng.normal() as f32 * 3.0).collect();
        // greedy == argmax
        let greedy = sample(&logits, &SamplingParams::default(), rng);
        assert_eq!(greedy as usize, argmax(&logits));
        // top-k sampling stays within the top-k set
        let k = rng.range(1, v);
        let params = SamplingParams {
            temperature: rng.f64() * 2.0 + 0.1,
            top_k: k,
            seed: 0,
        };
        let mut sorted: Vec<usize> = (0..v).collect();
        sorted.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
        let allowed: std::collections::HashSet<usize> = sorted[..k].iter().cloned().collect();
        for _ in 0..20 {
            let t = sample(&logits, &params, rng) as usize;
            assert!(allowed.contains(&t), "sampled {t} outside top-{k}");
        }
        // softmax/log_softmax consistency
        let p = softmax(&logits);
        let lp = log_softmax(&logits);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        for (a, b) in p.iter().zip(&lp) {
            assert!((a.ln() - b).abs() < 1e-6);
        }
    });
}

#[test]
fn prop_jsonx_roundtrip_random_values() {
    fn random_value(rng: &mut Rng, depth: usize) -> Value {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Value::Null,
            1 => Value::Bool(rng.chance(0.5)),
            2 => Value::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let n = rng.below(12);
                Value::Str(
                    (0..n)
                        .map(|_| *rng.choose(&['a', 'é', '"', '\\', '\n', '😀', 'z', '\t']))
                        .collect(),
                )
            }
            4 => Value::Arr((0..rng.below(4)).map(|_| random_value(rng, depth + 1)).collect()),
            _ => Value::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    check("jsonx_roundtrip", 200, |rng| {
        let v = random_value(rng, 0);
        let text = v.to_json();
        let back = jsonx::parse(&text).expect("parse own output");
        assert_eq!(v, back, "roundtrip mismatch for {text}");
    });
}

#[test]
fn prop_checkpoint_roundtrip_random_tensors() {
    check("checkpoint_roundtrip", 15, |rng| {
        let dir = std::env::temp_dir().join(format!(
            "rsb_prop_ckpt_{}_{}",
            std::process::id(),
            rng.next_u64()
        ));
        let path = dir.join("t.ckpt");
        let n = rng.range(1, 6);
        let tensors: Vec<(String, Tensor)> = (0..n)
            .map(|i| {
                let rank = rng.below(4);
                let shape: Vec<usize> = (0..rank).map(|_| rng.range(1, 5)).collect();
                let numel: usize = shape.iter().product();
                let t = match rng.below(3) {
                    0 => Tensor::f32(
                        shape,
                        (0..numel).map(|_| rng.normal() as f32).collect(),
                    )
                    .unwrap(),
                    1 => Tensor::i32(
                        shape,
                        (0..numel).map(|_| rng.next_u64() as i32).collect(),
                    )
                    .unwrap(),
                    _ => Tensor::u32(
                        shape,
                        (0..numel).map(|_| rng.next_u64() as u32).collect(),
                    )
                    .unwrap(),
                };
                (format!("t{i}"), t)
            })
            .collect();
        let refs: Vec<(String, &Tensor)> =
            tensors.iter().map(|(n, t)| (n.clone(), t)).collect();
        checkpoint::save(&path, &refs).unwrap();
        let loaded = checkpoint::load(&path).unwrap();
        assert_eq!(loaded.len(), tensors.len());
        for ((n1, t1), (n2, t2)) in tensors.iter().zip(&loaded) {
            assert_eq!(n1, n2);
            assert_eq!(t1, t2);
        }
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn prop_bpe_roundtrip_synthlang() {
    check("bpe_roundtrip", 8, |rng| {
        let mut gen = rsb::data::Generator::new(rng.next_u64());
        let text = gen.corpus(3000);
        let vocab = rng.range(40, 300);
        let bpe = Bpe::train(&text, vocab).unwrap();
        assert!(bpe.vocab_size() <= vocab);
        let ids = bpe.encode(&text);
        assert_eq!(bpe.decode(&ids), text);
        // token ids in range
        assert!(ids.iter().all(|&t| (t as usize) < bpe.vocab_size()));
    });
}

#[test]
fn prop_costmodel_monotonicity() {
    use rsb::costmodel::specdec::*;
    check("costmodel", 100, |rng| {
        let c = rng.f64() * 0.3 + 0.005;
        let gamma = rng.range(1, 30);
        let s1 = rng.f64();
        let s2 = (s1 + rng.f64() * (1.0 - s1)).min(1.0);
        // Thm 1 monotone increasing in sparsity, >= 1
        let a = thm1_speedup_vs_standard(c, gamma, s1);
        let b = thm1_speedup_vs_standard(c, gamma, s2);
        assert!(a >= 1.0 - 1e-12);
        assert!(b >= a - 1e-12);
        // Thm 2 monotone in alpha
        let alpha1 = rng.f64() * 0.98;
        let alpha2 = (alpha1 + 0.01).min(0.99);
        let t1 = thm2_speedup_vs_autoregressive(c, gamma, s1, alpha1);
        let t2 = thm2_speedup_vs_autoregressive(c, gamma, s1, alpha2);
        assert!(t2 >= t1 - 1e-12);
        // expected tokens within [1, gamma+1]
        let e = expected_tokens(alpha1, gamma);
        assert!((1.0..=(gamma as f64 + 1.0)).contains(&e));
    });
}

#[test]
fn prop_flops_model_bounds() {
    use rsb::model::{flops_with_sparsity, LayerSparsity};
    use rsb::runtime::artifact::ModelCfg;
    check("flops_bounds", 40, |rng| {
        let cfg = ModelCfg {
            size: "p".into(),
            arch: (*rng.choose(&["opt", "llama", "falcon"])).into(),
            act: "relu".into(),
            stage: 0,
            d_model: rng.range(8, 64) * 8,
            n_layers: rng.range(1, 8),
            n_heads: 8,
            d_ff: rng.range(8, 64) * 16,
            vocab: rng.range(16, 256) * 8,
            max_seq: 96,
            shift: 1.0,
            ffn_act: "relu".into(),
            gated: false,
            parallel_block: false,
            has_bias: false,
        };
        let sp: Vec<LayerSparsity> = (0..cfg.n_layers)
            .map(|_| LayerSparsity {
                qkv: rng.f64(),
                up: rng.f64(),
                ffn: rng.f64(),
            })
            .collect();
        let dense = flops_with_sparsity(&cfg, 32, &vec![LayerSparsity::default(); cfg.n_layers]);
        let sparse = flops_with_sparsity(&cfg, 32, &sp);
        assert!(sparse.total() <= dense.total() + 1e-6);
        assert!(sparse.total() > 0.0);
        // attention + lm head are sparsity-invariant
        assert!((sparse.attention - dense.attention).abs() < 1e-9);
        assert!((sparse.lm_head - dense.lm_head).abs() < 1e-9);
    });
}

#[test]
fn prop_rng_streams_independent() {
    check("rng_fold_in", 50, |rng| {
        let base = Rng::new(rng.next_u64());
        let mut a = base.fold_in(1);
        let mut b = base.fold_in(2);
        let mut same = 0;
        for _ in 0..64 {
            if a.next_u64() == b.next_u64() {
                same += 1;
            }
        }
        assert!(same < 2, "folded streams collide");
    });
}

/// ISSUE 2 satellite: `indexed_gemv` over a sorted live list must agree —
/// bit for bit — with `rowskip_gemv` over the activation masked to that
/// list (both iterate rows in ascending order, so the accumulation order is
/// identical), and with `dense_gemv` within float tolerance.
#[test]
fn prop_indexed_gemv_matches_masked_dense() {
    use rsb::sparse::{dense_gemv, indexed_gemv, rowskip_gemv};
    check("indexed_gemv", 60, |rng| {
        let f = rng.range(1, 96);
        let d = rng.range(1, 24);
        let w: Vec<f32> = (0..f * d).map(|_| rng.normal() as f32).collect();
        let a: Vec<f32> = (0..f)
            .map(|_| if rng.chance(0.8) { rng.normal() as f32 } else { 0.0 })
            .collect();
        // arbitrary sorted live subset (independent of a's zero pattern)
        let live: Vec<u32> = (0..f as u32).filter(|_| rng.chance(0.4)).collect();
        let masked: Vec<f32> = (0..f)
            .map(|i| if live.contains(&(i as u32)) { a[i] } else { 0.0 })
            .collect();
        let mut y_idx = vec![1.0f32; d]; // nonzero garbage: must be cleared
        let mut y_skip = vec![0.0f32; d];
        let mut y_dense = vec![0.0f32; d];
        indexed_gemv(&w, d, &live, &a, &mut y_idx);
        rowskip_gemv(&w, f, d, &masked, &mut y_skip);
        dense_gemv(&w, f, d, &masked, &mut y_dense);
        // indexed visits exactly the live rows; rowskip additionally skips
        // live rows whose activation is 0.0 — contributing nothing either
        // way, in the same ascending order: bitwise equal.
        assert_eq!(y_idx, y_skip, "indexed vs rowskip (f={f}, d={d})");
        for (x, y) in y_idx.iter().zip(&y_dense) {
            assert!((x - y).abs() < 1e-4, "indexed vs dense: {x} vs {y}");
        }
    });
}

/// ISSUE 3 satellite: per-row sparse batch FFN is bitwise-equal to dense
/// on ANY superset of each row's own active set, and rows never leak masks
/// across the batch — exercised end-to-end through the host backend's
/// decode step under random per-row `BatchMask`s.
#[test]
fn prop_per_row_batch_mask_superset_exact_and_isolated() {
    use rsb::hostexec::HostBackend;
    use rsb::runtime::artifact::ModelCfg;
    check("per_row_batch_mask", 10, |rng| {
        let b = rng.range(2, 5);
        let n_layers = rng.range(1, 3);
        let cfg = ModelCfg {
            size: "p".into(),
            arch: "opt".into(),
            act: "relu".into(),
            stage: 0,
            d_model: 8,
            n_layers,
            n_heads: 2,
            d_ff: rng.range(8, 24),
            vocab: 16,
            max_seq: 8,
            shift: 1.0,
            ffn_act: "relu".into(),
            gated: false,
            parallel_block: false,
            has_bias: true,
        };
        let (l, f, v) = (cfg.n_layers, cfg.d_ff, cfg.vocab);
        let be = HostBackend::random(cfg, rng.next_u64(), b, 4).unwrap();
        let kv = Tensor::zeros_f32(be.kv_shape());
        let pos = Tensor::i32(vec![b], vec![0; b]).unwrap();
        let toks = Tensor::i32(
            vec![b, 1],
            (0..b).map(|_| rng.below(v) as i32).collect(),
        )
        .unwrap();
        let dense = be
            .decode(&kv, &pos, &toks, &BatchMask::dense(b, l, f))
            .unwrap();
        let dl = dense.logits.as_f32().unwrap();
        let fm = dense.ffn_mask.as_f32().unwrap();
        // each row: its own observed active set + random false alarms
        let mut mask = BatchMask::dense(b, l, f);
        for row in 0..b {
            let bits: Vec<bool> = (0..l * f)
                .map(|i| {
                    let (li, fi) = (i / f, i % f);
                    fm[(li * b + row) * f + fi] != 0.0 || rng.chance(0.3)
                })
                .collect();
            mask.set_sparse(row, bits).unwrap();
        }
        let sparse = be.decode(&kv, &pos, &toks, &mask).unwrap();
        assert_eq!(
            dl,
            sparse.logits.as_f32().unwrap(),
            "per-row supersets must reproduce dense bitwise"
        );
        assert_eq!(dense.kv.as_f32().unwrap(), sparse.kv.as_f32().unwrap());
        // leak check: empty one random row's mask; every OTHER row must
        // stay bitwise identical to dense, the emptied row must not
        let victim = rng.below(b);
        let victim_fired = (0..l * f).any(|i| {
            let (li, fi) = (i / f, i % f);
            fm[(li * b + victim) * f + fi] != 0.0
        });
        let mut leak = mask.clone();
        leak.set_sparse(victim, vec![false; l * f]).unwrap();
        let out = be.decode(&kv, &pos, &toks, &leak).unwrap();
        let ol = out.logits.as_f32().unwrap();
        for row in 0..b {
            let (got, want) = (&ol[row * v..(row + 1) * v], &dl[row * v..(row + 1) * v]);
            if row == victim {
                if victim_fired {
                    assert_ne!(got, want, "emptied row {row} must change");
                }
            } else {
                assert_eq!(got, want, "row {victim}'s mask leaked into row {row}");
            }
        }
    });
}

/// BatchMask algebra: every row is a subset of the union, so the per-slot
/// average density can never exceed the union density (the bench_decode
/// acceptance gate), and a dense row collapses the union to all-ones.
#[test]
fn prop_batch_mask_union_dominates_rows() {
    check("batch_mask_union", 40, |rng| {
        let b = rng.range(1, 6);
        let l = rng.range(1, 3);
        let f = rng.range(4, 40);
        let mut m = BatchMask::dense(b, l, f);
        let mut any_dense = false;
        for row in 0..b {
            if rng.chance(0.25) {
                any_dense = true; // leave the row dense
            } else {
                let bits: Vec<bool> = (0..l * f).map(|_| rng.chance(0.3)).collect();
                m.set_sparse(row, bits).unwrap();
            }
        }
        let rows: Vec<usize> = (0..b).collect();
        let union = m.union_density(&rows);
        let avg: f64 =
            rows.iter().map(|&r| m.row_density(r)).sum::<f64>() / b as f64;
        assert!(avg <= union + 1e-12, "avg {avg} > union {union}");
        for &r in &rows {
            assert!(m.row_density(r) <= union + 1e-12);
        }
        if any_dense {
            assert_eq!(union, 1.0, "a dense row must force the union dense");
        }
        // the union tensor agrees with the density helper
        let t = m.union_tensor().unwrap();
        assert!((t.density().unwrap() - union).abs() < 1e-12);
    });
}

/// ISSUE 5 satellite: `MaskWindow::union_bits` equals the naive OR of the
/// trailing `window` recorded token masks, for arbitrary window sizes, γ
/// and ring occupancy — and its reported density is the popcount.
#[test]
fn prop_mask_window_union_is_or_of_trailing_masks() {
    use rsb::engine::MaskWindow;
    check("mask_window_union", 40, |rng| {
        let l = rng.range(1, 4);
        let f = rng.range(1, 80); // odd widths exercise the u64 packing tail
        let cap = rng.range(1, 12);
        let mut w = MaskWindow::new(l, f, cap);
        let mut history: Vec<Vec<bool>> = Vec::new();
        for _ in 0..30 {
            let bits: Vec<bool> = (0..l * f).map(|_| rng.chance(0.3)).collect();
            w.push_bits(&bits).unwrap();
            history.push(bits);
            assert_eq!(w.len(), history.len().min(cap));
            let window = rng.range(1, 2 * cap + 2);
            // naive OR over the trailing min(window, cap) in-ring masks
            let mut want = vec![false; l * f];
            for recent in history.iter().rev().take(cap).take(window) {
                for (o, &b) in want.iter_mut().zip(recent) {
                    *o |= b;
                }
            }
            assert_eq!(w.union_bits(window), want, "window {window}");
            let (t, density) = w.union(window);
            let live = want.iter().filter(|&&b| b).count();
            assert!((density - live as f64 / (l * f) as f64).abs() < 1e-12);
            assert_eq!(t.count_nonzero().unwrap(), live);
            // density_of is the popcount fraction of any mask tensor
            assert!((MaskWindow::density_of(&t).unwrap() - density).abs() < 1e-12);
        }
    });
}

/// ISSUE 5 satellite: the host verify pass over ANY mask that is a
/// superset of every fed position's true liveness is bitwise-equal to
/// dense verification — logits, KV and the union mask — while dropping a
/// live neuron from the mask changes the logits.
#[test]
fn prop_host_verify_superset_bitwise_equals_dense() {
    use rsb::hostexec::HostBackend;
    use rsb::runtime::artifact::ModelCfg;
    check("host_verify_superset", 10, |rng| {
        let n_layers = rng.range(1, 3);
        let cfg = ModelCfg {
            size: "p".into(),
            arch: "opt".into(),
            act: "relu".into(),
            stage: 0,
            d_model: 8,
            n_layers,
            n_heads: 2,
            d_ff: rng.range(8, 24),
            vocab: 16,
            max_seq: 16,
            shift: 1.0,
            ffn_act: "relu".into(),
            gated: false,
            parallel_block: false,
            has_bias: true,
        };
        let (l, f, v) = (cfg.n_layers, cfg.d_ff, cfg.vocab);
        let be = HostBackend::random(cfg, rng.next_u64(), 1, 4).unwrap();
        let prompt: Vec<i32> = (0..4).map(|_| rng.below(v) as i32).collect();
        let pre = be
            .prefill(&Tensor::i32(vec![1, 4], prompt).unwrap(), false)
            .unwrap();
        let g = rng.range(1, 5);
        let toks = Tensor::i32(
            vec![1, g],
            (0..g).map(|_| rng.below(v) as i32).collect(),
        )
        .unwrap();
        let ones = Tensor::ones_f32(vec![l, f]);
        let dense = be.verify(&pre.kv, 4, &toks, &ones).unwrap();
        // superset mask: the observed union + random false alarms
        let union = dense.union_mask.as_f32().unwrap();
        let sup: Vec<f32> = union
            .iter()
            .map(|&u| if u != 0.0 || rng.chance(0.3) { 1.0 } else { 0.0 })
            .collect();
        let sup_t = Tensor::f32(vec![l, f], sup.clone()).unwrap();
        let sparse = be.verify(&pre.kv, 4, &toks, &sup_t).unwrap();
        assert_eq!(
            dense.logits.as_f32().unwrap(),
            sparse.logits.as_f32().unwrap(),
            "superset verify must be bitwise-equal to dense"
        );
        assert_eq!(dense.kv.as_f32().unwrap(), sparse.kv.as_f32().unwrap());
        assert_eq!(
            dense.union_mask.as_f32().unwrap(),
            sparse.union_mask.as_f32().unwrap()
        );
        // dropping one live neuron must show up in the logits
        if let Some(first_live) = sup.iter().position(|&x| x != 0.0) {
            if union[first_live] != 0.0 {
                let mut dropped = sup.clone();
                dropped[first_live] = 0.0;
                let out = be
                    .verify(
                        &pre.kv,
                        4,
                        &toks,
                        &Tensor::f32(vec![l, f], dropped).unwrap(),
                    )
                    .unwrap();
                assert_ne!(
                    dense.logits.as_f32().unwrap(),
                    out.logits.as_f32().unwrap(),
                    "dropping a live neuron must change verification"
                );
            }
        }
    });
}

/// ISSUE 2 satellite: `FfnWeights::from_row_major` round-trip — the
/// up-projection transpose is exact and self-inverse, and the constructed
/// weights compute the same FFN as a direct row-major reference.
#[test]
fn prop_ffn_from_row_major_round_trip() {
    check("ffn_from_row_major", 40, |rng| {
        let f = rng.range(1, 48);
        let d = rng.range(1, 16);
        let w_up: Vec<f32> = (0..d * f).map(|_| rng.normal() as f32).collect();
        let b_up: Vec<f32> = (0..f).map(|_| rng.normal() as f32 * 0.1).collect();
        let w_down: Vec<f32> = (0..f * d).map(|_| rng.normal() as f32).collect();
        let w = FfnWeights::from_row_major(f, d, &w_up, b_up.clone(), w_down.clone());
        assert_eq!(w.up_row_major(), w_up, "transpose must round-trip exactly");
        // rebuild from the round-tripped layout: identical weights
        let w2 = FfnWeights::from_row_major(f, d, &w.up_row_major(), b_up.clone(), w_down.clone());
        assert_eq!(w.w_up_t, w2.w_up_t);
        // forward agreement with a direct row-major reference:
        // y = relu(x @ w_up + b) @ w_down
        let x: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        let mut pre = b_up.clone();
        for (i, &xi) in x.iter().enumerate() {
            for j in 0..f {
                pre[j] += xi * w_up[i * f + j];
            }
        }
        let mut want = vec![0.0f64; d];
        for (j, &p) in pre.iter().enumerate() {
            if p > 0.0 {
                for k in 0..d {
                    want[k] += p as f64 * w_down[j * d + k] as f64;
                }
            }
        }
        let mut got = vec![0.0f32; d];
        dense_ffn_matvec(&w, &x, &mut got);
        for (g, w_) in got.iter().zip(&want) {
            assert!(
                (*g as f64 - w_).abs() < 1e-3 * (1.0 + w_.abs()),
                "ffn mismatch: {g} vs {w_}"
            );
        }
    });
}

#[test]
fn prop_per_layer_live_counts_sum_to_mask_popcount() {
    // ISSUE 6: the per-layer split of a mask row must account for every
    // live neuron exactly once — sum over layers of `row_live_counts` is
    // the row's popcount, and sparse rows agree with `layer_live_counts`
    // on the raw bits.
    check("per_layer_live_counts", 40, |rng| {
        let n_layers = rng.range(1, 6);
        let d_ff = rng.range(1, 64);
        let b = rng.range(1, 5);
        let mut mask = BatchMask::dense(b, n_layers, d_ff);
        let mut row_bits: Vec<Option<Vec<bool>>> = vec![None; b];
        for row in 0..b {
            if rng.chance(0.7) {
                let density = if rng.chance(0.5) { 0.3 } else { 0.05 };
                let bits: Vec<bool> =
                    (0..n_layers * d_ff).map(|_| rng.chance(density)).collect();
                mask.set_sparse(row, bits.clone()).unwrap();
                row_bits[row] = Some(bits);
            }
        }
        for row in 0..b {
            let counts = mask.row_live_counts(row);
            assert_eq!(counts.len(), n_layers);
            let total: usize = counts.iter().sum();
            match &row_bits[row] {
                Some(bits) => {
                    let popcount = bits.iter().filter(|&&x| x).count();
                    assert_eq!(total, popcount, "live counts must sum to popcount");
                    assert_eq!(
                        counts,
                        layer_live_counts(bits, n_layers, d_ff),
                        "per-layer split must match the raw bits"
                    );
                }
                None => assert_eq!(total, n_layers * d_ff, "dense row = all live"),
            }
            // density agreement with the flat per-row view the engine logs
            let density = total as f64 / (n_layers * d_ff) as f64;
            assert!(
                (density - mask.row_density(row)).abs() < 1e-12,
                "row_live_counts and row_density disagree"
            );
        }
    });
}
