//! Paged-KV equivalence under serving churn: drive the host backend
//! through a long random admit/decode/evict schedule twice — once against
//! the dense `KvBatch` (positional write-back) and once against a `KvPool`
//! (native `decode_paged`) — and require byte-identical behaviour
//! throughout: logits rows, observed FFN masks, and the stored K/V itself.
//! This is the integration-level counterpart of the allocator prop tests
//! inside `runtime::paged` and the single-step bit-identity test inside
//! `hostexec::backend`.

use rsb::engine::{BatchMask, ExecBackend, KvBatch, KvPool};
use rsb::hostexec::HostBackend;
use rsb::runtime::artifact::ModelCfg;
use rsb::runtime::Tensor;
use rsb::util::rng::Rng;

fn cfg(arch: &str) -> ModelCfg {
    ModelCfg {
        size: "t".into(),
        arch: arch.into(),
        act: "relu".into(),
        stage: 0,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 40,
        max_seq: 20,
        shift: 1.0,
        ffn_act: "relu".into(),
        gated: false,
        parallel_block: false,
        has_bias: true,
    }
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit mismatch at {i}");
    }
}

/// 150 random scheduling events over 3 slots: every decode step must read
/// and write the page pool byte-identically to the dense batch cache.
#[test]
fn random_admit_evict_schedule_is_bit_identical_dense_vs_paged() {
    for arch in ["opt", "llama"] {
        let c = cfg(arch);
        let (b, prefill_t, max_seq) = (3usize, 6usize, c.max_seq);
        let vocab = c.vocab;
        let (n_layers, d_ff) = (c.n_layers, c.d_ff);
        let be = HostBackend::random(c, 23, b, prefill_t).unwrap();
        assert!(be.supports_paged_kv() && be.decode_writes_positions_only());

        let mut dense = KvBatch::new(&be.kv_shape()).unwrap();
        // page_size 3 does not divide max_seq 20: exercises the ragged
        // last page; 24 pages cover the worst case (3 slots * 7 pages)
        let mut pool = KvPool::new(&be.kv_shape(), 3, 24).unwrap();
        let mut pos: Vec<Option<usize>> = vec![None; b];
        let mut tok: Vec<u32> = vec![0; b];
        let mut rng = Rng::new(77);

        for step in 0..150 {
            // random admissions into free slots
            for slot in 0..b {
                if pos[slot].is_none() && rng.chance(0.35) {
                    let len = rng.range(1, prefill_t + 1);
                    let mut padded = vec![0i32; prefill_t];
                    for p in padded.iter_mut().take(len) {
                        *p = rng.range(1, vocab) as i32;
                    }
                    let tok_t = Tensor::i32(vec![1, prefill_t], padded).unwrap();
                    let pre = be.prefill(&tok_t, false).unwrap();
                    dense.pack_row(slot, &pre.kv).unwrap();
                    pool.reserve(slot, max_seq).unwrap();
                    // copy the full padded bucket (garbage past `len`
                    // included) so the two stores hold the same bytes;
                    // decode overwrites those positions before reading them
                    pool.write_row_positions(slot, &pre.kv, 0..prefill_t).unwrap();
                    pos[slot] = Some(len);
                    tok[slot] = rng.range(1, vocab) as u32;
                }
            }
            // random evictions + forced eviction at the context edge
            for slot in 0..b {
                let full = pos[slot].is_some_and(|p| p + 1 >= max_seq);
                if pos[slot].is_some() && (full || rng.chance(0.05)) {
                    dense.clear_row(slot);
                    pool.release(slot);
                    pos[slot] = None;
                }
            }
            let stepped: Vec<(usize, usize)> = pos
                .iter()
                .enumerate()
                .filter_map(|(s, p)| p.map(|p| (s, p)))
                .collect();
            if stepped.is_empty() {
                continue;
            }

            // one decode step against each store
            let mut pd = vec![0i32; b];
            let mut pp = vec![-1i32; b];
            let mut toks = vec![0i32; b];
            for &(slot, p) in &stepped {
                pd[slot] = p as i32;
                pp[slot] = p as i32;
                toks[slot] = tok[slot] as i32;
            }
            let mask = BatchMask::dense(b, n_layers, d_ff);
            let tok_t = Tensor::i32(vec![b, 1], toks).unwrap();
            let out_d = be
                .decode(
                    &dense.to_tensor(),
                    &Tensor::i32(vec![b], pd).unwrap(),
                    &tok_t,
                    &mask,
                )
                .unwrap();
            dense.write_decode_positions(&out_d.kv, &stepped).unwrap();
            for &(slot, p) in &stepped {
                pool.ensure_to(slot, p).unwrap();
            }
            let out_p = be
                .decode_paged(&mut pool, &Tensor::i32(vec![b], pp).unwrap(), &tok_t, &mask)
                .unwrap();

            // logits + observed FFN mask: byte-identical on every live row
            let (ld, lp) = (out_d.logits.as_f32().unwrap(), out_p.logits.as_f32().unwrap());
            let (fd, fp) = (
                out_d.ffn_mask.as_f32().unwrap(),
                out_p.ffn_mask.as_f32().unwrap(),
            );
            for &(slot, _) in &stepped {
                assert_bits_eq(
                    &ld[slot * vocab..(slot + 1) * vocab],
                    &lp[slot * vocab..(slot + 1) * vocab],
                    &format!("{arch} step {step} slot {slot} logits"),
                );
                for l in 0..n_layers {
                    let at = (l * b + slot) * d_ff;
                    assert_bits_eq(
                        &fd[at..at + d_ff],
                        &fp[at..at + d_ff],
                        &format!("{arch} step {step} slot {slot} layer {l} ffn mask"),
                    );
                }
            }
            // the stored K/V itself: pool pages materialize to exactly the
            // dense cache (released rows read back as zeros in both)
            if step % 10 == 0 {
                assert_bits_eq(
                    dense.to_tensor().as_f32().unwrap(),
                    pool.materialize_batch().unwrap().as_f32().unwrap(),
                    &format!("{arch} step {step} full kv"),
                );
            }
            // advance: feed each live row its own next token
            for &(slot, p) in &stepped {
                pos[slot] = Some(p + 1);
                tok[slot] = rng.range(1, vocab) as u32;
            }
        }
        // drain everything: pool must return to empty
        for slot in 0..b {
            if pos[slot].is_some() {
                pool.release(slot);
            }
        }
        assert_eq!(pool.pages_in_use(), 0);
        assert!(pool.high_water() > 0);
    }
}
