//! Engine-level request-lifecycle observability (ISSUE 9): completion
//! timings attribution, request-id-tagged trace spans, SLO drift monitors
//! and the engine-side metrics reset. Host backend only — no PJRT.

use std::sync::Arc;

use rsb::engine::{Completion, Engine, EngineConfig, NeuronPolicy, PagedKvCfg};
use rsb::hostexec::HostBackend;
use rsb::obs::{Phase, TraceSink};
use rsb::runtime::artifact::ModelCfg;
use rsb::runtime::Tensor;
use rsb::util::rng::Rng;

fn cfg() -> ModelCfg {
    ModelCfg {
        size: "t".into(),
        arch: "opt".into(),
        act: "relu".into(),
        stage: 0,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 40,
        max_seq: 20,
        shift: 1.0,
        ffn_act: "relu".into(),
        gated: false,
        parallel_block: false,
        has_bias: true,
    }
}

fn engine(decode_b: usize, ecfg: EngineConfig) -> Engine {
    let be = HostBackend::random(cfg(), 5, decode_b, 6).unwrap();
    Engine::new(Box::new(be), ecfg).unwrap()
}

fn run_to_completion(eng: &mut Engine) -> Vec<Completion> {
    let mut done = Vec::new();
    for _ in 0..10_000 {
        if !eng.has_work() {
            return done;
        }
        done.extend(eng.step().unwrap());
    }
    panic!("engine did not drain in 10k steps");
}

/// Every completion carries a lifecycle attribution whose pieces are
/// internally consistent: non-negative, prefill compute below the wall
/// window it ran in, and queue + ttft-to-retire roughly covering total.
#[test]
fn completion_timings_attribute_the_request_lifecycle() {
    let mut eng = engine(2, EngineConfig::default());
    for (prompt, max_new) in [(vec![3u32, 4], 6usize), (vec![7, 8, 9, 2, 5], 4), (vec![1], 8)] {
        eng.submit(prompt, max_new);
    }
    let done = run_to_completion(&mut eng);
    assert_eq!(done.len(), 3);
    for c in &done {
        let t = &c.timings;
        assert!(t.total_ms > 0.0, "req {}: empty total", c.id);
        assert!(t.ttft_ms > 0.0, "req {}: empty ttft", c.id);
        assert!(t.prefill_ms > 0.0, "req {}: prefill compute missing", c.id);
        assert!(t.queue_ms >= 0.0 && t.kv_wait_ms >= 0.0);
        assert!(t.prefill_stall_ms >= 0.0 && t.decode_ms >= 0.0);
        assert_eq!(t.kv_wait_ms, 0.0, "dense KV cannot block admission");
        assert_eq!(t.prefill_chunks, 1, "one-shot prefill is one chunk");
        // ttft splits total: what came before the first token, plus decode
        assert!(
            t.ttft_ms <= t.total_ms + 0.1,
            "req {}: ttft {} > total {}",
            c.id,
            t.ttft_ms,
            t.total_ms
        );
        assert!(
            (t.ttft_ms + t.decode_ms - t.total_ms).abs() < 0.5,
            "req {}: ttft {} + decode {} should cover total {}",
            c.id,
            t.ttft_ms,
            t.decode_ms,
            t.total_ms
        );
        // the sketch saw every completion
    }
    assert_eq!(eng.metrics.request_latency_ms.len(), 3);
    assert!(eng.metrics.request_latency_ms.percentile(50.0) > 0.0);
}

/// Chunked prefill reports its chunk count in the timings and stall time
/// stays non-negative (wall >= compute inside the admit->prefill window).
#[test]
fn chunked_prefill_timings_count_chunks() {
    let mut eng = engine(
        1,
        EngineConfig {
            prefill_chunk: 2,
            ..EngineConfig::default()
        },
    );
    eng.submit(vec![7, 8, 9, 2, 5], 3);
    let done = run_to_completion(&mut eng);
    assert_eq!(done.len(), 1);
    let t = &done[0].timings;
    assert_eq!(t.prefill_chunks, 3, "5 prompt tokens in chunks of 2");
    assert!(t.prefill_ms > 0.0);
    assert!(t.prefill_stall_ms >= 0.0);
}

/// With a trace sink attached, every request contributes a tagged
/// `request` lifecycle span plus a `queue-wait` span, and the tags
/// round-trip into the Chrome-trace dump as `args.req`.
#[test]
fn trace_carries_request_id_correlation() {
    let sink = Arc::new(TraceSink::new(1 << 12));
    let mut eng = engine(2, EngineConfig::default());
    eng.set_trace(Some(sink.clone()));
    let ids: Vec<u64> = (0..3)
        .map(|i| eng.submit(vec![3 + i as u32, 4], 4))
        .collect();
    let done = run_to_completion(&mut eng);
    assert_eq!(done.len(), 3);

    let events = sink.events();
    let req_spans: Vec<_> = events.iter().filter(|e| e.phase == Phase::Request).collect();
    assert_eq!(req_spans.len(), 3, "one lifecycle span per request");
    let mut tagged: Vec<u64> = req_spans.iter().map(|e| e.req).collect();
    tagged.sort_unstable();
    let mut want = ids.clone();
    want.sort_unstable();
    assert_eq!(tagged, want, "lifecycle spans carry the engine request ids");
    assert_eq!(
        events.iter().filter(|e| e.phase == Phase::QueueWait).count(),
        3,
        "one queue-wait span per admission"
    );
    // per-request backend work (prefill) inherits the ambient tag
    assert!(
        events
            .iter()
            .any(|e| e.phase == Phase::Prefill && e.req != rsb::obs::trace::NO_REQ),
        "prefill spans must be request-tagged"
    );
    // batched decode steps stay untagged (they serve every slot at once)
    assert!(
        events
            .iter()
            .filter(|e| e.phase == Phase::DecodeStep)
            .all(|e| e.req == rsb::obs::trace::NO_REQ),
        "batched decode work cannot be attributed to one request"
    );
}

/// A density SLO with an impossible ceiling must walk ok -> warn -> breach
/// under sustained enforced traffic, count the breach, and recover state
/// via the engine-level reset.
#[test]
fn density_slo_breaches_under_sustained_violation_and_resets() {
    // static half-dense mask enforced from step 0: every enforced row's
    // density lands far above the 1e-3 ceiling
    let mut rng = Rng::new(11);
    let bits: Vec<bool> = (0..2 * 32).map(|_| rng.chance(0.5)).collect();
    let mut eng = engine(
        1,
        EngineConfig {
            policy: NeuronPolicy::Static(Tensor::mask_from_bits(vec![2, 32], &bits).unwrap()),
            slo_density_ceil: Some(1e-3),
            ..EngineConfig::default()
        },
    );
    eng.submit(vec![3, 4], 16);
    let done = run_to_completion(&mut eng);
    assert_eq!(done.len(), 1);

    let slo = &eng.metrics.slo;
    assert_eq!(slo.len(), 1);
    assert_eq!(slo[0].kind, "density");
    assert_eq!(slo[0].state.name(), "breach", "16 enforced steps over a 1e-3 ceiling");
    assert!(slo[0].breaches >= 1);
    assert!(slo[0].windowed > 1e-3);

    // engine-level reset clears the monitor but keeps it configured
    eng.reset_metrics();
    assert_eq!(eng.metrics.slo.len(), 1);
    assert_eq!(eng.metrics.slo[0].state.name(), "ok");
    assert_eq!(eng.metrics.slo[0].breaches, 0);
    assert_eq!(eng.metrics.slo[0].n, 0);
}

/// A generous ceiling never leaves Ok — the monitor only reacts to
/// sustained violation, not to being configured.
#[test]
fn generous_slo_stays_ok() {
    let mut rng = Rng::new(11);
    let bits: Vec<bool> = (0..2 * 32).map(|_| rng.chance(0.4)).collect();
    let mut eng = engine(
        1,
        EngineConfig {
            policy: NeuronPolicy::Static(Tensor::mask_from_bits(vec![2, 32], &bits).unwrap()),
            slo_density_ceil: Some(0.99),
            slo_p99_ms: Some(60_000.0),
            ..EngineConfig::default()
        },
    );
    eng.submit(vec![3, 4], 16);
    run_to_completion(&mut eng);
    for s in &eng.metrics.slo {
        assert_eq!(s.state.name(), "ok", "{} flapped without violation", s.kind);
        assert_eq!(s.breaches, 0);
    }
}

/// `reset_metrics` on a paged engine re-anchors the pool high-water mark:
/// the next step's gauge refresh must not resurrect the pre-reset peak.
#[test]
fn reset_reanchors_paged_high_water() {
    let mut eng = engine(
        2,
        EngineConfig {
            paged_kv: Some(PagedKvCfg {
                page_size: 4,
                n_pages: 10,
            }),
            ..EngineConfig::default()
        },
    );
    eng.submit(vec![3, 4, 5, 6], 8);
    eng.submit(vec![7, 8, 9], 8);
    run_to_completion(&mut eng);
    assert!(eng.metrics.kv_pages_high_water > 0);
    eng.reset_metrics();
    assert_eq!(eng.metrics.kv_pages_high_water, 0);
    assert_eq!(eng.metrics.kv_pages_total, 10, "geometry survives the reset");
    // drive more work: the gauge re-grows from the new epoch only
    eng.submit(vec![1], 2);
    run_to_completion(&mut eng);
    assert!(eng.metrics.kv_pages_high_water > 0);
    assert!(eng.metrics.kv_pages_in_use == 0);
}

/// The build-info block identifies the running configuration.
#[test]
fn build_info_names_backend_and_quant() {
    let eng = engine(1, EngineConfig::default());
    let bi = eng.build_info();
    assert_eq!(bi.str_of("backend").unwrap(), "host");
    assert_eq!(bi.str_of("quant").unwrap(), "f32");
    assert_eq!(bi.str_of("version").unwrap(), env!("CARGO_PKG_VERSION"));
    assert!(!bi.str_of("simd").unwrap().is_empty());
    assert!(bi.f64_of("uptime_seconds").unwrap() >= 0.0);
}

/// The standalone Prometheus rendering of a live engine passes the same
/// structural expectations the server-side test pins.
#[test]
fn prometheus_text_covers_a_live_engine() {
    let mut eng = engine(1, EngineConfig::default());
    eng.submit(vec![3, 4], 4);
    run_to_completion(&mut eng);
    let text = eng.prometheus_text();
    assert!(text.contains("# TYPE pallas_tokens_generated_total counter"));
    assert!(text.contains("pallas_tokens_generated_total 4\n"));
    assert!(text.contains("# TYPE pallas_request_latency_ms histogram"));
    assert!(text.contains("_bucket{le=\"+Inf\"}"));
    assert!(text.contains("pallas_build_info{"));
    assert!(text.contains("pallas_uptime_seconds"));
    for line in text.lines() {
        assert!(
            line.is_empty() || line.starts_with('#') || line.starts_with("pallas_"),
            "non-pallas line: {line:?}"
        );
    }
}

/// Submitting requests faster than a tiny page pool can host them forces
/// the queue head to wait on pages — the wait shows up in `kv_wait_ms`,
/// not in generic queue time.
#[test]
fn kv_page_wait_is_attributed_when_the_pool_saturates() {
    let mut eng = engine(
        2,
        EngineConfig {
            // pages for ~one request at a time: the second must wait for
            // the first to retire and free its reservation
            paged_kv: Some(PagedKvCfg {
                page_size: 4,
                n_pages: 4,
            }),
            ..EngineConfig::default()
        },
    );
    eng.submit(vec![3, 4], 8);
    let second = eng.submit(vec![7, 8], 8);
    let done = run_to_completion(&mut eng);
    assert_eq!(done.len(), 2);
    let waited = done.iter().find(|c| c.id == second).unwrap();
    assert!(
        waited.timings.kv_wait_ms > 0.0,
        "the blocked request must attribute its page wait"
    );
    assert!(waited.timings.queue_ms >= waited.timings.kv_wait_ms);
}
