//! Integration tests over the real AOT artifacts (tiny model): runtime
//! loading, cross-entry numerical consistency, engine/specdec/server
//! behaviour. Requires the `xla` feature and `make artifacts` to have
//! produced `artifacts/tiny_opt_relu_s0`. (The host-backend counterpart,
//! `tests/hostexec.rs`, needs neither.)
#![cfg(feature = "xla")]

use std::path::PathBuf;
use std::sync::Arc;

use rsb::engine::sampler::log_softmax;
use rsb::engine::{
    AcceptMode, Engine, EngineConfig, NeuronPolicy, SamplingParams, SpecDecoder, VerifyMask,
};
use rsb::runtime::{cpu_client, Arg, Model, Tensor};

const TINY: &str = "tiny_opt_relu_s0";

fn artifacts() -> PathBuf {
    // tests run from the package root
    let p = PathBuf::from("artifacts");
    assert!(
        p.join(TINY).join("manifest.json").exists(),
        "run `make artifacts` first"
    );
    p
}

fn tiny() -> Arc<Model> {
    Arc::new(Model::open(cpu_client().unwrap(), &artifacts(), TINY).unwrap())
}

#[test]
fn manifest_and_init_consistency() {
    let model = tiny();
    let m = &model.manifest;
    assert_eq!(m.model_id, TINY);
    assert_eq!(m.config.arch, "opt");
    // rust param-count mirror agrees with python
    assert_eq!(rsb::model::param_count(&m.config), m.param_count);
    let params = model.init_params(7).unwrap();
    assert_eq!(params.len(), m.params.len());
    for (spec, t) in m.params.iter().zip(&params.tensors) {
        assert_eq!(spec.shape, t.shape, "{}", spec.name);
    }
    // deterministic
    let again = model.init_params(7).unwrap();
    for (a, b) in params.tensors.iter().zip(&again.tensors) {
        assert_eq!(a, b);
    }
    let diff = model.init_params(8).unwrap();
    assert!(params.tensors.iter().zip(&diff.tensors).any(|(a, b)| a != b));
}

#[test]
fn checkpoint_roundtrip_through_model() {
    let model = tiny();
    let params = model.init_params(3).unwrap();
    let dir = std::env::temp_dir().join(format!("rsb_it_ckpt_{}", std::process::id()));
    let path = dir.join("tiny.ckpt");
    model.save_params(&path, &params).unwrap();
    let loaded = model.load_params(&path).unwrap();
    for (a, b) in params.tensors.iter().zip(&loaded.tensors) {
        assert_eq!(a, b);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Teacher-forced NLL via sequential decode1 must match the score entry —
/// the rust-side analogue of python's decode≡full test, across two entirely
/// different HLO programs.
#[test]
fn decode_chain_matches_score_entry() {
    let model = tiny();
    let mut params = model.init_params(1).unwrap();
    params.upload(model.client()).unwrap();
    let b = model.manifest.buckets.clone();
    let c = model.manifest.config.clone();
    let width = b.train_t + 1;
    // a fixed token window
    let doc: Vec<u32> = (0..width as u32).map(|i| (i * 7 + 3) % c.vocab as u32).collect();

    // score path (batch row 0; rows padded with the same window)
    let score = model.entry("score").unwrap();
    let mut flat = Vec::new();
    for _ in 0..b.score_b {
        flat.extend(doc.iter().map(|&t| t as i32));
    }
    let toks = Tensor::i32(vec![b.score_b, width], flat).unwrap();
    let mut args: Vec<Arg> = params.buffers().unwrap().iter().map(Arg::Device).collect();
    args.push(Arg::Host(&toks));
    let outs = score.execute(&args).unwrap();
    let nll_score: Vec<f32> = outs[0].as_f32().unwrap()[..width - 1].to_vec();

    // decode path: prefill bucket + sequential decode
    let prefill = model.entry("prefill").unwrap();
    let decode1 = model.entry("decode1").unwrap();
    let tp = b.prefill_t;
    let ptoks = Tensor::i32(vec![1, tp], doc[..tp].iter().map(|&t| t as i32).collect()).unwrap();
    let mut args: Vec<Arg> = params.buffers().unwrap().iter().map(Arg::Device).collect();
    args.push(Arg::Host(&ptoks));
    let pouts = prefill.execute(&args).unwrap();
    // prefill logits at position i predict token i+1
    let plog = pouts[0].as_f32().unwrap();
    for i in 0..tp - 1 {
        let lp = log_softmax(&plog[i * c.vocab..(i + 1) * c.vocab]);
        let want = nll_score[i] as f64;
        let got = -lp[doc[i + 1] as usize];
        assert!(
            (want - got).abs() < 3e-3,
            "prefill NLL mismatch at {i}: {want} vs {got}"
        );
    }
    let mut kv = pouts[1].clone();
    let ones = Tensor::ones_f32(vec![c.n_layers, c.d_ff]);
    for (step, i) in (tp - 1..width - 1).enumerate() {
        // feed token i at position i (prefill already wrote 0..tp-1; the
        // token at tp-1 is re-fed as the first decode input — consistent
        // with the overwrite-before-attend invariant)
        let pos = Tensor::i32(vec![1], vec![i as i32]).unwrap();
        let tk = Tensor::i32(vec![1, 1], vec![doc[i] as i32]).unwrap();
        let mut a: Vec<Arg> = params.buffers().unwrap().iter().map(Arg::Device).collect();
        a.push(Arg::Host(&kv));
        a.push(Arg::Host(&pos));
        a.push(Arg::Host(&tk));
        a.push(Arg::Host(&ones));
        let outs = decode1.execute(&a).unwrap();
        kv = outs[1].clone();
        let lp = log_softmax(outs[0].as_f32().unwrap());
        let want = nll_score[i] as f64;
        let got = -lp[doc[i + 1] as usize];
        assert!(
            (want - got).abs() < 3e-3,
            "decode NLL mismatch at {i} (step {step}): {want} vs {got}"
        );
    }
}

#[test]
fn engine_greedy_is_deterministic_and_batch_invariant() {
    let model = tiny();
    let params = model.init_params(2).unwrap();
    let mut engine = Engine::with_model(model.clone(), params, EngineConfig::default()).unwrap();
    let prompt: Vec<u32> = vec![5, 9, 13, 21];
    // submit the same greedy prompt four times (fills the whole batch)
    for _ in 0..4 {
        engine.submit(prompt.clone(), 10);
    }
    let mut done = engine.run_to_completion().unwrap();
    done.sort_by_key(|d| d.id);
    assert_eq!(done.len(), 4);
    for d in &done[1..] {
        assert_eq!(d.tokens, done[0].tokens, "batch rows interfered");
    }
    // and a second engine run reproduces it
    let params = model.init_params(2).unwrap();
    let mut engine2 = Engine::with_model(model, params, EngineConfig::default()).unwrap();
    engine2.submit(prompt, 10);
    let done2 = engine2.run_to_completion().unwrap();
    assert_eq!(done2[0].tokens, done[0].tokens);
}

#[test]
fn engine_tracks_sparsity_and_respects_max_tokens() {
    let model = tiny();
    let params = model.init_params(4).unwrap();
    let mut engine = Engine::with_model(model, params, EngineConfig::default()).unwrap();
    let id = engine.submit(vec![1, 2, 3], 6);
    let mut done = Vec::new();
    let mut tracker_sparsity = None;
    while engine.has_work() {
        // peek at the tracker before the slot is retired
        for slot in 0..engine.decode_b {
            if let Some(tr) = engine.tracker_for_slot(slot) {
                if tr.steps() > 0 {
                    tracker_sparsity = Some(tr.aggregated_sparsity());
                    for w in tr.curve.windows(2) {
                        assert!(w[1] <= w[0] + 1e-12);
                    }
                }
            }
        }
        done.extend(engine.step().unwrap());
    }
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, id);
    assert_eq!(done[0].tokens.len(), 6);
    let s = tracker_sparsity.expect("tracker never populated");
    assert!((0.0..=1.0).contains(&s));
}

/// KEY serving invariant: speculative decoding with draft == target and
/// greedy acceptance must reproduce plain greedy decoding exactly, with a
/// 100% acceptance rate.
#[test]
fn specdec_self_draft_matches_greedy() {
    let model = tiny();
    let n = 14usize;
    let prompt: Vec<u32> = vec![3, 1, 4, 1, 5, 9, 2, 6];

    // plain greedy via the engine
    let params = model.init_params(5).unwrap();
    let mut engine = Engine::with_model(model.clone(), params, EngineConfig::default()).unwrap();
    engine.submit(prompt.clone(), n);
    let greedy = engine.run_to_completion().unwrap().remove(0).tokens;

    // speculative with the same model as its own draft
    let tp = model.init_params(5).unwrap();
    let dp = model.init_params(5).unwrap();
    let mut dec = SpecDecoder::with_models(
        model.clone(),
        tp,
        model.clone(),
        dp,
        4,
        AcceptMode::Greedy,
        VerifyMask::Dense,
        0,
    )
    .unwrap();
    let (tokens, stats) = dec.generate(&prompt, n).unwrap();
    assert_eq!(tokens, greedy, "speculative output diverged from greedy");
    assert!(
        stats.acceptance_rate() > 0.999,
        "self-draft must always be accepted, got {}",
        stats.acceptance_rate()
    );
}

#[test]
fn specdec_sparse_mask_preserves_selfdraft_structure() {
    // With aggregated masking the verification is approximated; acceptance
    // can drop below 1.0 but the decoder must still emit n tokens and the
    // measured window sparsity must be sane.
    let model = tiny();
    let tp = model.init_params(5).unwrap();
    let dp = model.init_params(5).unwrap();
    let mut dec = SpecDecoder::with_models(
        model.clone(),
        tp,
        model,
        dp,
        4,
        AcceptMode::Greedy,
        VerifyMask::Aggregated { window: 16 },
        0,
    )
    .unwrap();
    let (tokens, stats) = dec.generate(&[2, 7, 1, 8], 12).unwrap();
    assert_eq!(tokens.len(), 12);
    assert!((0.0..=1.0).contains(&stats.s_agg_gamma));
    assert!(stats.c_measured > 0.0);
}

#[test]
fn neuron_mask_all_ones_equals_default_and_zero_mask_changes_output() {
    let model = tiny();
    let mut params = model.init_params(6).unwrap();
    params.upload(model.client()).unwrap();
    let c = model.manifest.config.clone();
    let decode1 = model.entry("decode1").unwrap();
    let kv = Tensor::zeros_f32(model.manifest.kv_shape(1));
    let pos = Tensor::i32(vec![1], vec![0]).unwrap();
    let tk = Tensor::i32(vec![1, 1], vec![7]).unwrap();
    let run = |mask: &Tensor| -> Vec<f32> {
        let mut a: Vec<Arg> = params.buffers().unwrap().iter().map(Arg::Device).collect();
        a.push(Arg::Host(&kv));
        a.push(Arg::Host(&pos));
        a.push(Arg::Host(&tk));
        a.push(Arg::Host(mask));
        decode1.execute(&a).unwrap()[0].as_f32().unwrap().to_vec()
    };
    let ones = run(&Tensor::ones_f32(vec![c.n_layers, c.d_ff]));
    let ones2 = run(&Tensor::ones_f32(vec![c.n_layers, c.d_ff]));
    assert_eq!(ones, ones2, "decode must be deterministic");
    let zeros = run(&Tensor::zeros_f32(vec![c.n_layers, c.d_ff]));
    assert_ne!(ones, zeros, "zero neuron mask must change the logits");
}

/// ISSUE 1 satellite: at recall floor 1.0 (shadow mode) the Reuse policy
/// must never change a single output token vs Dense — the predictor
/// measures recall/precision but the escape hatch keeps every step dense.
#[test]
fn reuse_policy_at_recall_floor_one_matches_dense_exactly() {
    let model = tiny();
    let prompt: Vec<u32> = vec![5, 9, 13, 21, 2, 7];
    let n = 12usize;

    let params = model.init_params(2).unwrap();
    let mut dense = Engine::with_model(model.clone(), params, EngineConfig::default()).unwrap();
    dense.submit(prompt.clone(), n);
    let dense_done = dense.run_to_completion().unwrap();

    let params = model.init_params(2).unwrap();
    let cfg = EngineConfig {
        policy: NeuronPolicy::Reuse { window: 3, union_k: 3 },
        recall_floor: 1.0,
        ..EngineConfig::default()
    };
    let mut reuse = Engine::with_model(model, params, cfg).unwrap();
    reuse.submit(prompt, n);
    let reuse_done = reuse.run_to_completion().unwrap();

    assert_eq!(
        reuse_done[0].tokens, dense_done[0].tokens,
        "shadow-mode reuse degraded output tokens"
    );
    // shadow mode: recall was measured, nothing was enforced
    assert_eq!(reuse.metrics.enforced_steps, 0);
    assert!(
        !reuse.metrics.predictor_recall.is_empty(),
        "shadow recall was never measured"
    );
    for i in 0..reuse.metrics.predictor_recall.len() {
        // recall values are probabilities
        let r = reuse.metrics.predictor_recall.percentile(100.0 * i as f64 / 12.0);
        assert!((0.0..=1.0).contains(&r));
    }
    assert!(reuse.metrics.report().contains("predictor:"));
}

/// Completion::queue_ms satellite: the measured admission wait reaches the
/// completion record (and is sane).
#[test]
fn queue_wait_is_carried_into_completions() {
    let model = tiny();
    let params = model.init_params(3).unwrap();
    let mut engine = Engine::with_model(model, params, EngineConfig::default()).unwrap();
    // 2x the batch size so half the requests queue behind a full batch
    let n_req = engine.decode_b * 2;
    for i in 0..n_req {
        engine.submit(vec![1 + i as u32, 4, 2], 6);
    }
    let mut done = engine.run_to_completion().unwrap();
    assert_eq!(done.len(), n_req);
    done.sort_by_key(|d| d.id);
    for d in &done {
        assert!(d.queue_ms >= 0.0);
        assert!(
            d.queue_ms <= d.total_ms,
            "queue wait cannot exceed total latency"
        );
    }
    // the second wave waited for at least the first decode steps
    let first_wave_max = done[..engine.decode_b]
        .iter()
        .map(|d| d.queue_ms)
        .fold(0.0f64, f64::max);
    let second_wave_min = done[engine.decode_b..]
        .iter()
        .map(|d| d.queue_ms)
        .fold(f64::INFINITY, f64::min);
    assert!(
        second_wave_min >= first_wave_max,
        "queued wave should wait longer ({second_wave_min} vs {first_wave_max})"
    );
}

#[test]
fn server_roundtrip_over_tcp() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let server = std::thread::spawn(move || {
        let model = tiny();
        let params = model.init_params(0).unwrap();
        let engine = Engine::with_model(model, params, EngineConfig::default()).unwrap();
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", Some(2), Some(ready_tx), 0)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();
    for i in 0..2 {
        let resp = client.request(i, "ab ba", 4, 0.0).unwrap();
        assert_eq!(resp.get("id").and_then(|v| v.as_i64()), Some(i as i64));
        assert_eq!(resp.get("tokens").and_then(|v| v.as_usize()), Some(4));
        assert!(resp.get("text").is_some());
        assert!(
            resp.get("queue_ms").and_then(|v| v.as_f64()).unwrap_or(-1.0) >= 0.0,
            "response must carry the measured queue wait"
        );
    }
    assert_eq!(server.join().unwrap().unwrap(), 2);
}

/// ISSUE 1 satellite: malformed requests get a JSON error line back (with
/// the request id echoed when one could be parsed) instead of silence.
#[test]
fn server_replies_json_error_to_malformed_requests() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let server = std::thread::spawn(move || {
        let model = tiny();
        let params = model.init_params(0).unwrap();
        let engine = Engine::with_model(model, params, EngineConfig::default()).unwrap();
        rsb::server::serve(engine, bpe, "127.0.0.1:0", Some(1), Some(ready_tx), 0)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();

    // not JSON at all -> error with null id
    client.send_line("this is not json").unwrap();
    let resp = client.recv().unwrap();
    assert!(resp.get("error").and_then(|v| v.as_str()).is_some());
    assert_eq!(resp.get("id"), Some(&rsb::jsonx::Value::Null));

    // valid JSON missing `prompt` -> error echoing the id
    client.send_line("{\"id\": 7, \"max_tokens\": 4}").unwrap();
    let resp = client.recv().unwrap();
    assert!(resp
        .get("error")
        .and_then(|v| v.as_str())
        .unwrap()
        .contains("prompt"));
    assert_eq!(resp.get("id").and_then(|v| v.as_i64()), Some(7));

    // bad policy spec -> error, not a crash
    client
        .send_line("{\"id\": 8, \"prompt\": \"ab\", \"policy\": \"warp\"}")
        .unwrap();
    let resp = client.recv().unwrap();
    assert!(resp
        .get("error")
        .and_then(|v| v.as_str())
        .unwrap()
        .contains("policy"));

    // the connection is still healthy: a valid request completes normally
    let resp = client.request(9, "ab ba", 3, 0.0).unwrap();
    assert!(resp.get("error").is_none());
    assert_eq!(resp.get("tokens").and_then(|v| v.as_usize()), Some(3));
    assert_eq!(server.join().unwrap().unwrap(), 1);
}

#[test]
fn sampling_params_affect_engine_output() {
    let model = tiny();
    let params = model.init_params(9).unwrap();
    let mut engine = Engine::with_model(model, params, EngineConfig::default()).unwrap();
    let prompt = vec![4, 2, 4, 2];
    engine.submit_with(
        prompt.clone(),
        12,
        SamplingParams {
            temperature: 1.5,
            top_k: 0,
            seed: 1,
        },
    );
    engine.submit_with(
        prompt,
        12,
        SamplingParams {
            temperature: 1.5,
            top_k: 0,
            seed: 2,
        },
    );
    let mut done = engine.run_to_completion().unwrap();
    done.sort_by_key(|d| d.id);
    assert_ne!(
        done[0].tokens, done[1].tokens,
        "different seeds at T=1.5 should diverge"
    );
}
