//! End-to-end tests of the serving engine on the host execution backend —
//! no PJRT client, no AOT artifacts, runs under
//! `cargo test --no-default-features` (the CI host gate).
//!
//! Covers the ISSUE 2 + ISSUE 3 acceptance surface:
//! - shadow-mode equivalence: every `NeuronPolicy` at `recall_floor >= 1.0`
//!   (all-ones mask for `Static`) is token-identical to dense decode, on
//!   all three architectures — per slot, under the per-slot `BatchMask`
//!   contract;
//! - per-slot isolation: an enforcing slot never perturbs a dense slot's
//!   tokens in the same batch;
//! - prefill ≡ decode-chain bit-exactness (causality + KV write/attend
//!   ordering);
//! - prefill seeding: step 0 after prefill can already enforce a sparse
//!   mask (no W dense warmup steps);
//! - the committed golden fixture: greedy token IDs pinned against the L2
//!   JAX reference (`tools/make_host_fixture.py`), plus the predictor's
//!   recall/density counter schedule under an enforcing Reuse policy;
//! - the TCP server speaking the same protocol over a host engine,
//!   including the per-request sparsity fields in the JSON reply;
//! - ISSUE 7: the golden fixture decoded at int8 (`--quant q8`'s backend
//!   path) keeps every pinned token whose argmax margin exceeds the
//!   observed quantization drift, and `time_to_first_token_ms` is stamped
//!   at prefill sampling, not at the first decode step.

use std::sync::Arc;

use rsb::engine::{BatchMask, Engine, EngineConfig, NeuronPolicy, SamplingParams};
use rsb::hostexec::HostBackend;
use rsb::runtime::artifact::ModelCfg;
use rsb::runtime::{ExecBackend, Tensor};

fn cfg(arch: &str) -> ModelCfg {
    let act = if arch == "llama" { "silu" } else { "relu" };
    ModelCfg {
        size: "t".into(),
        arch: arch.into(),
        act: act.into(),
        stage: 0,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 40,
        max_seq: 20,
        shift: 1.0,
        ffn_act: act.into(),
        gated: arch == "llama",
        parallel_block: arch == "falcon",
        has_bias: arch == "opt",
    }
}

fn engine(arch: &str, ecfg: EngineConfig) -> Engine {
    let backend = HostBackend::random(cfg(arch), 42, 2, 6).unwrap();
    Engine::new(Box::new(backend), ecfg).unwrap()
}

/// Mirror of the fixture config in tools/make_host_fixture.py — keep in
/// sync with the generator.
fn fixture_cfg() -> ModelCfg {
    ModelCfg {
        size: "fixture".into(),
        arch: "opt".into(),
        act: "relu".into(),
        stage: 0,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        vocab: 48,
        max_seq: 24,
        shift: 1.0,
        ffn_act: "relu".into(),
        gated: false,
        parallel_block: false,
        has_bias: true,
    }
}

fn fixture_backend(decode_b: usize) -> HostBackend {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/host_tiny.ckpt");
    HostBackend::from_checkpoint(fixture_cfg(), &path, decode_b, 8).unwrap()
}

/// ISSUE 2 satellite: with `recall_floor >= 1.0` (shadow mode; all-ones
/// mask for the always-enforcing `Static`) every policy variant produces
/// token-for-token identical output to host dense decode.
#[test]
fn shadow_mode_matches_dense_for_every_policy_and_arch() {
    for arch in ["opt", "llama", "falcon"] {
        let prompt: Vec<u32> = vec![5, 9, 13, 21];
        let n = 12usize;
        let mut dense = engine(arch, EngineConfig::default());
        dense.submit(prompt.clone(), n);
        let want = dense.run_to_completion().unwrap().remove(0).tokens;
        assert_eq!(want.len(), n);

        let c = cfg(arch);
        let policies: Vec<(&str, NeuronPolicy)> = vec![
            ("dense", NeuronPolicy::Dense),
            (
                "static(ones)",
                NeuronPolicy::Static(Tensor::ones_f32(vec![c.n_layers, c.d_ff])),
            ),
            ("reuse", NeuronPolicy::Reuse { window: 3, union_k: 3 }),
            ("topp", NeuronPolicy::TopP { window: 3, budget: 0.9 }),
        ];
        for (name, policy) in policies {
            let is_static = matches!(policy, NeuronPolicy::Static(_));
            let is_predictive = policy.is_predictive();
            let ecfg = EngineConfig {
                policy,
                recall_floor: 1.0,
                ..EngineConfig::default()
            };
            let mut e = engine(arch, ecfg);
            e.submit(prompt.clone(), n);
            let got = e.run_to_completion().unwrap().remove(0).tokens;
            assert_eq!(got, want, "{arch}/{name}: shadow mode changed tokens");
            if is_static {
                // all-ones mask is enforced but cannot change anything
                assert!(e.metrics.enforced_steps > 0, "{arch}/{name}");
            } else {
                assert_eq!(e.metrics.enforced_steps, 0, "{arch}/{name}");
            }
            if is_predictive {
                assert!(
                    !e.metrics.predictor_recall.is_empty(),
                    "{arch}/{name}: shadow recall was never measured"
                );
            }
        }
    }
}

/// An enforcing predictive policy must still complete, with sane counters —
/// and a sub-1.0 floor on a stable stream must actually enforce.
#[test]
fn enforcing_reuse_completes_with_sparse_steps() {
    for arch in ["opt", "llama", "falcon"] {
        let ecfg = EngineConfig {
            policy: NeuronPolicy::Reuse { window: 2, union_k: 2 },
            recall_floor: 0.05,
            probe_every: 4,
            ..EngineConfig::default()
        };
        let mut e = engine(arch, ecfg);
        e.submit(vec![2, 4, 8], 12);
        let done = e.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 12, "{arch}");
        assert!(e.metrics.enforced_steps > 0, "{arch}: nothing was enforced");
        assert!(e.metrics.probe_steps > 0, "{arch}: probes never ran");
        let density = e.metrics.mask_density.mean();
        assert!(
            density > 0.0 && density <= 1.0,
            "{arch}: bad mask density {density}"
        );
    }
}

/// Same prompt in every slot of one batch must decode identically — the
/// host attention/KV indexing cannot leak across rows.
#[test]
fn batch_rows_decode_independently() {
    let mut e = engine("opt", EngineConfig::default());
    let prompt: Vec<u32> = vec![7, 3, 11];
    for _ in 0..2 {
        e.submit(prompt.clone(), 10);
    }
    let mut done = e.run_to_completion().unwrap();
    done.sort_by_key(|d| d.id);
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].tokens, done[1].tokens, "batch rows interfered");
    // and a fresh engine reproduces the run (host backend is deterministic)
    let mut e2 = engine("opt", EngineConfig::default());
    e2.submit(prompt, 10);
    assert_eq!(e2.run_to_completion().unwrap()[0].tokens, done[0].tokens);
}

/// Prefill over T tokens and the equivalent prefill-then-decode chain are
/// BIT-identical on the host backend: per-token math is sequential f32, so
/// causality bugs, KV ordering bugs or position mix-ups show up exactly.
#[test]
fn decode_chain_is_bit_identical_to_prefill() {
    for arch in ["opt", "llama", "falcon"] {
        let be = HostBackend::random(cfg(arch), 7, 1, 8).unwrap();
        let doc: Vec<i32> = vec![2, 7, 1, 8, 4, 9, 6, 3];
        let full = be
            .prefill(&Tensor::i32(vec![1, 8], doc.clone()).unwrap(), false)
            .unwrap();
        let flog = full.logits.as_f32().unwrap();
        let v = be.config().vocab;

        // chain: prefill the first 4 tokens (padded), then decode the rest
        let mut padded = doc.clone();
        for p in padded.iter_mut().skip(4) {
            *p = 0;
        }
        let pre = be
            .prefill(&Tensor::i32(vec![1, 8], padded).unwrap(), false)
            .unwrap();
        let plog = pre.logits.as_f32().unwrap();
        for g in 0..4 {
            assert_eq!(
                &plog[g * v..(g + 1) * v],
                &flog[g * v..(g + 1) * v],
                "{arch}: padding leaked into causal position {g}"
            );
        }
        let mut kv = pre.kv.clone();
        let mask = BatchMask::dense(1, be.config().n_layers, be.config().d_ff);
        for g in 4..8 {
            let out = be
                .decode(
                    &kv,
                    &Tensor::i32(vec![1], vec![g as i32]).unwrap(),
                    &Tensor::i32(vec![1, 1], vec![doc[g]]).unwrap(),
                    &mask,
                )
                .unwrap();
            kv = out.kv;
            assert_eq!(
                out.logits.as_f32().unwrap(),
                &flog[g * v..(g + 1) * v],
                "{arch}: decode at position {g} diverged from prefill"
            );
        }
        assert_eq!(
            kv.as_f32().unwrap(),
            full.kv.as_f32().unwrap(),
            "{arch}: final chain KV differs from prefill KV"
        );
    }
}

/// ISSUE 2 satellite: the committed golden fixture. Greedy decode of the
/// checkpoint under the host backend must reproduce the token IDs computed
/// by the L2 JAX reference (tools/make_host_fixture.py; every argmax is
/// decided by a margin ~4 orders of magnitude above f32 noise).
#[test]
fn golden_fixture_greedy_tokens_are_pinned() {
    let backend = fixture_backend(2);
    assert_eq!(backend.model_id(), "fixture_opt_relu_s0");
    let mut e = Engine::new(Box::new(backend), EngineConfig::default()).unwrap();
    e.submit(vec![3, 1, 4, 1, 5], 10);
    let done = e.run_to_completion().unwrap();
    assert_eq!(
        done[0].tokens,
        vec![27, 1, 32, 32, 32, 28, 28, 39, 39, 39],
        "golden greedy decode drifted from the L2 reference"
    );
    assert_eq!(e.metrics.tokens_generated, 10);
    assert_eq!(e.metrics.enforced_steps, 0);
}

/// Golden fixture, part 2: the predictor counter schedule under an
/// enforcing Reuse policy is fully deterministic. Prefill seeding (window
/// 2 over the 5-token prompt) fills the ring and takes 3 in-prompt shadow
/// measurements at admit, so enforcement starts at decode step 0 (the
/// ISSUE 3 satellite: no W dense warmup steps); probes (probe_every 4,
/// never at step 0) land at steps {4, 8}; the remaining 10 steps all run
/// this slot's row sparse.
#[test]
fn golden_fixture_pins_recall_and_density_counters() {
    let backend = fixture_backend(2);
    let ecfg = EngineConfig {
        policy: NeuronPolicy::Reuse { window: 2, union_k: 2 },
        recall_floor: 0.05, // tiny floor: enforcement gated only by warmup
        probe_every: 4,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(Box::new(backend), ecfg).unwrap();
    e.submit(vec![3, 1, 4, 1, 5], 12);
    // admit + step 0 in one call: the seeded slot must enforce immediately
    let first = e.step().unwrap();
    assert!(first.is_empty());
    assert_eq!(
        e.metrics.predictor_recall.len(),
        3,
        "seeding scores prompt positions 2..5 at admit"
    );
    assert_eq!(
        e.metrics.enforced_steps, 1,
        "prefill-seeded slot must enforce at decode step 0"
    );
    let done = e.run_to_completion().unwrap();
    assert_eq!(done[0].tokens.len(), 12);
    assert_eq!(e.metrics.steps, 12);
    assert_eq!(e.metrics.probe_steps, 2, "probes at steps 4 and 8");
    assert_eq!(
        e.metrics.enforced_steps, 10,
        "every non-probe step runs the slot's row sparse"
    );
    assert_eq!(e.metrics.enforced_rows, 10, "one slot, one row per step");
    assert_eq!(
        e.metrics.predictor_recall.len(),
        5,
        "3 seed evals + one shadow eval per probe (4, 8)"
    );
    assert_eq!(e.metrics.fallback_events, 0);
    assert_eq!(e.metrics.mask_density.len(), 10);
    assert_eq!(
        e.metrics.union_mask_density.len(),
        10,
        "union density sampled once per enforced step"
    );
    let density = e.metrics.mask_density.mean();
    assert!(
        density > 0.0 && density < 1.0,
        "enforced masks must be sparse, got density {density}"
    );
    // single occupied slot: its own mask IS the occupied union
    assert!(
        (density - e.metrics.union_mask_density.mean()).abs() < 1e-12,
        "solo slot density must equal the union density"
    );
    // the per-slot split pins the same schedule to slot 0 and nothing else
    assert_eq!(e.metrics.per_slot[0].enforced_rows, 10);
    assert_eq!(e.metrics.per_slot[0].mask_density.len(), 10);
    assert_eq!(e.metrics.per_slot[0].recall.len(), 5);
    assert_eq!(e.metrics.per_slot[1].enforced_rows, 0, "empty slot stayed idle");
    // ...and reaches the client through the completion record
    let d = done[0].mask_density.expect("enforced request reports density");
    assert!(d > 0.0 && d < 1.0);
    assert_eq!(done[0].enforced_rows, 10);
    assert_eq!(done[0].fallbacks, 0);
    for i in 0..=10 {
        let r = e.metrics.predictor_recall.percentile(10.0 * i as f64);
        assert!((0.0..=1.0).contains(&r), "recall {r} out of range");
    }
}

/// ISSUE 3 per-slot isolation: a batch mixing a dense-policy request with
/// an enforcing Reuse request must leave the dense request's tokens
/// bit-identical to a solo dense run — one slot's mask never leaks into
/// another row.
#[test]
fn enforcing_slot_never_perturbs_a_dense_slot() {
    let prompt_dense: Vec<u32> = vec![5, 9, 13, 21];
    let prompt_reuse: Vec<u32> = vec![2, 4, 8];
    let n = 10usize;
    let mut solo = engine("opt", EngineConfig::default());
    solo.submit(prompt_dense.clone(), n);
    let want = solo.run_to_completion().unwrap().remove(0).tokens;

    let ecfg = EngineConfig {
        recall_floor: 0.05,
        probe_every: 4,
        ..EngineConfig::default()
    };
    let mut e = engine("opt", ecfg);
    let dense_id = e.submit(prompt_dense, n);
    e.submit_with_policy(
        prompt_reuse,
        n,
        SamplingParams::default(),
        Some(NeuronPolicy::Reuse { window: 2, union_k: 2 }),
    );
    let mut done = e.run_to_completion().unwrap();
    done.sort_by_key(|d| d.id);
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].id, dense_id);
    assert_eq!(
        done[0].tokens, want,
        "enforcing slot 1 leaked into dense slot 0"
    );
    // the reuse slot really did enforce (prefill-seeded, floor 0.05)
    assert!(e.metrics.enforced_steps > 0, "nothing was enforced");
    assert_eq!(e.metrics.per_slot[0].enforced_rows, 0, "dense slot enforced?");
    assert!(e.metrics.per_slot[1].enforced_rows > 0, "reuse slot never enforced");
    // per-request observability: the dense request reports no density, the
    // sparse one reports its own
    assert_eq!(done[0].mask_density, None);
    assert_eq!(done[0].enforced_rows, 0);
    let d = done[1].mask_density.expect("reuse request reports density");
    assert!(d > 0.0 && d <= 1.0);
    assert!(done[1].enforced_rows > 0);
}

/// The JSON-lines TCP server end-to-end over the host backend — the whole
/// serving stack with no PJRT anywhere in the process.
#[test]
fn server_roundtrip_over_host_backend() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let server = std::thread::spawn(move || {
        let backend = HostBackend::random(cfg("opt"), 0, 2, 6).unwrap();
        let ecfg = EngineConfig {
            policy: NeuronPolicy::Reuse { window: 4, union_k: 4 },
            recall_floor: 1.0,
            ..EngineConfig::default()
        };
        let engine = Engine::new(Box::new(backend), ecfg).unwrap();
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", Some(2), Some(ready_tx), 0)
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();
    // a malformed line first: the error path must not wedge the engine
    client.send_line("{\"id\": 3, \"max_tokens\": 2}").unwrap();
    let resp = client.recv().unwrap();
    assert!(resp
        .get("error")
        .and_then(|v| v.as_str())
        .unwrap()
        .contains("prompt"));
    for i in 0..2 {
        let resp = client.request(i, "ab ba", 4, 0.0).unwrap();
        assert_eq!(resp.get("id").and_then(|v| v.as_i64()), Some(i as i64));
        assert_eq!(resp.get("tokens").and_then(|v| v.as_usize()), Some(4));
        assert!(resp.get("text").is_some());
        // per-request sparsity fields: shadow mode (floor 1.0) never
        // enforces, so density is null and the counters are zero
        assert_eq!(resp.get("mask_density"), Some(&rsb::jsonx::Value::Null));
        assert_eq!(resp.get("enforced_rows").and_then(|v| v.as_usize()), Some(0));
        assert_eq!(resp.get("fallbacks").and_then(|v| v.as_usize()), Some(0));
    }
    assert_eq!(server.join().unwrap().unwrap(), 2);
}

fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// ISSUE 7: the golden fixture at int8. Teacher-force the pinned f32
/// continuation through the f32 and q8 backend paths side by side. At
/// each step, when the f32 argmax margin exceeds twice the observed q8
/// logit drift the token provably cannot move — assert it doesn't (this
/// exercises the whole q8 decode path; a wrong scale or layout would blow
/// the drift up instead). The drift itself is bounded at 15% of the logit
/// scale — an order of magnitude above what per-neuron symmetric int8
/// costs, an order of magnitude below what a broken path produces. If
/// every step is margin-decidable, the greedy q8 engine run must
/// reproduce the pinned sequence end to end.
#[test]
fn golden_fixture_tokens_survive_q8_quantization() {
    use rsb::hostexec::QuantMode;
    let pinned: Vec<u32> = vec![27, 1, 32, 32, 32, 28, 28, 39, 39, 39];
    let prompt = vec![3i32, 1, 4, 1, 5];
    let f32_be = fixture_backend(1);
    let q8_be = fixture_backend(1).with_quant(QuantMode::Q8);
    let c = fixture_cfg();
    let v = c.vocab;
    let mask = BatchMask::dense(1, c.n_layers, c.d_ff);

    // padded prefill (bucket 8), step-0 logits at the last prompt position
    let mut padded = prompt.clone();
    padded.resize(8, 0);
    let toks = Tensor::i32(vec![1, 8], padded).unwrap();
    let pf = f32_be.prefill(&toks, false).unwrap();
    let pq = q8_be.prefill(&toks, false).unwrap();
    let mut lf = pf.logits.as_f32().unwrap()[4 * v..5 * v].to_vec();
    let mut lq = pq.logits.as_f32().unwrap()[4 * v..5 * v].to_vec();
    let (mut kv_f, mut kv_q) = (pf.kv, pq.kv);

    let mut decided = 0usize;
    for (k, &want) in pinned.iter().enumerate() {
        assert_eq!(argmax(&lf), want as usize, "f32 fixture drifted at step {k}");
        let scale = lf.iter().fold(0.0f32, |m, &x| m.max(x.abs())).max(1.0);
        let drift = lf
            .iter()
            .zip(&lq)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()));
        assert!(
            drift <= 0.15 * scale,
            "step {k}: q8 logits drifted {drift} (scale {scale}) — quant path broken"
        );
        let mut top = lf.clone();
        top.sort_by(|a, b| b.partial_cmp(a).unwrap());
        if top[0] - top[1] > 2.0 * drift {
            assert_eq!(
                argmax(&lq),
                want as usize,
                "step {k}: q8 flipped a margin-decided token"
            );
            decided += 1;
        }
        if k + 1 == pinned.len() {
            break;
        }
        let pos = Tensor::i32(vec![1], vec![(prompt.len() + k) as i32]).unwrap();
        let tok = Tensor::i32(vec![1, 1], vec![want as i32]).unwrap();
        let of = f32_be.decode(&kv_f, &pos, &tok, &mask).unwrap();
        let oq = q8_be.decode(&kv_q, &pos, &tok, &mask).unwrap();
        lf = of.logits.as_f32().unwrap().to_vec();
        lq = oq.logits.as_f32().unwrap().to_vec();
        (kv_f, kv_q) = (of.kv, oq.kv);
    }
    assert!(decided > 0, "q8 drift swamped every argmax margin");

    // greedy q8 engine run: deterministic, and pinned outright when every
    // step above was margin-decidable
    let run = || {
        let be = fixture_backend(2).with_quant(QuantMode::Q8);
        let mut e = Engine::new(Box::new(be), EngineConfig::default()).unwrap();
        e.submit(vec![3, 1, 4, 1, 5], 10);
        e.run_to_completion().unwrap().remove(0).tokens
    };
    let (t1, t2) = (run(), run());
    assert_eq!(t1.len(), 10);
    assert_eq!(t1, t2, "q8 greedy decode is not deterministic");
    if decided == pinned.len() {
        assert_eq!(t1, pinned, "q8 greedy run diverged from the pinned tokens");
    }
}

/// Wraps the host backend and stalls every decode step, so a TTFT stamped
/// at the first decode step would be off by at least one stall.
struct SlowDecode {
    inner: HostBackend,
    delay: std::time::Duration,
}

impl ExecBackend for SlowDecode {
    fn kind(&self) -> &'static str {
        "host-slow"
    }
    fn model_id(&self) -> &str {
        self.inner.model_id()
    }
    fn config(&self) -> &ModelCfg {
        self.inner.config()
    }
    fn decode_b(&self) -> usize {
        self.inner.decode_b()
    }
    fn prefill_t(&self) -> usize {
        self.inner.prefill_t()
    }
    fn supports_row_masks(&self) -> bool {
        self.inner.supports_row_masks()
    }
    fn prefill(
        &self,
        tokens: &Tensor,
        report_ffn_mask: bool,
    ) -> rsb::Result<rsb::runtime::PrefillOut> {
        self.inner.prefill(tokens, report_ffn_mask)
    }
    fn decode(
        &self,
        kv: &Tensor,
        pos: &Tensor,
        tokens: &Tensor,
        mask: &BatchMask,
    ) -> rsb::Result<rsb::runtime::DecodeOut> {
        std::thread::sleep(self.delay);
        self.inner.decode(kv, pos, tokens, mask)
    }
}

/// ISSUE 7: `time_to_first_token_ms` is stamped when the first token is
/// sampled from prefill logits in `admit()`. With every decode step
/// stalled 30ms, a TTFT stamped at the first decode step would measure at
/// least one stall; the prefill-stamped one stays well under it.
#[test]
fn ttft_is_stamped_at_prefill_not_first_decode_step() {
    let delay = std::time::Duration::from_millis(30);
    let backend = SlowDecode {
        inner: HostBackend::random(cfg("opt"), 42, 2, 6).unwrap(),
        delay,
    };
    let mut e = Engine::new(Box::new(backend), EngineConfig::default()).unwrap();
    let t0 = std::time::Instant::now();
    e.submit(vec![5, 9, 13], 6);
    let done = e.run_to_completion().unwrap();
    let total_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(done[0].tokens.len(), 6);
    assert!(total_ms >= 60.0, "decode stall did not engage ({total_ms}ms)");
    let ttft = e.metrics.time_to_first_token_ms.mean();
    assert!(
        ttft < 15.0,
        "TTFT {ttft}ms includes decode latency (stall is 30ms/step)"
    );
}

/// Sampling still behaves on the host backend (temperature diverges seeds).
#[test]
fn sampling_diverges_across_seeds() {
    let mut e = engine("opt", EngineConfig::default());
    let prompt = vec![4, 2, 4, 2];
    for seed in [1, 2] {
        e.submit_with(
            prompt.clone(),
            12,
            SamplingParams {
                temperature: 1.5,
                top_k: 0,
                seed,
            },
        );
    }
    let mut done = e.run_to_completion().unwrap();
    done.sort_by_key(|d| d.id);
    assert_ne!(
        done[0].tokens, done[1].tokens,
        "different seeds at T=1.5 should diverge"
    );
}
