//! End-to-end tests of the serving engine on the host execution backend —
//! no PJRT client, no AOT artifacts, runs under
//! `cargo test --no-default-features` (the CI host gate).
//!
//! Covers the ISSUE 2 acceptance surface:
//! - shadow-mode equivalence: every `NeuronPolicy` at `recall_floor >= 1.0`
//!   (all-ones mask for `Static`) is token-identical to dense decode, on
//!   all three architectures;
//! - prefill ≡ decode-chain bit-exactness (causality + KV write/attend
//!   ordering);
//! - the committed golden fixture: greedy token IDs pinned against the L2
//!   JAX reference (`tools/make_host_fixture.py`), plus the predictor's
//!   recall/density counter schedule under an enforcing Reuse policy;
//! - the TCP server speaking the same protocol over a host engine.

use std::sync::Arc;

use rsb::engine::{Engine, EngineConfig, NeuronPolicy, SamplingParams};
use rsb::hostexec::HostBackend;
use rsb::runtime::artifact::ModelCfg;
use rsb::runtime::{ExecBackend, Tensor};

fn cfg(arch: &str) -> ModelCfg {
    let act = if arch == "llama" { "silu" } else { "relu" };
    ModelCfg {
        size: "t".into(),
        arch: arch.into(),
        act: act.into(),
        stage: 0,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        vocab: 40,
        max_seq: 20,
        shift: 1.0,
        ffn_act: act.into(),
        gated: arch == "llama",
        parallel_block: arch == "falcon",
        has_bias: arch == "opt",
    }
}

fn engine(arch: &str, ecfg: EngineConfig) -> Engine {
    let backend = HostBackend::random(cfg(arch), 42, 2, 6).unwrap();
    Engine::new(Box::new(backend), ecfg).unwrap()
}

/// Mirror of the fixture config in tools/make_host_fixture.py — keep in
/// sync with the generator.
fn fixture_cfg() -> ModelCfg {
    ModelCfg {
        size: "fixture".into(),
        arch: "opt".into(),
        act: "relu".into(),
        stage: 0,
        d_model: 16,
        n_layers: 2,
        n_heads: 2,
        d_ff: 64,
        vocab: 48,
        max_seq: 24,
        shift: 1.0,
        ffn_act: "relu".into(),
        gated: false,
        parallel_block: false,
        has_bias: true,
    }
}

fn fixture_backend(decode_b: usize) -> HostBackend {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/host_tiny.ckpt");
    HostBackend::from_checkpoint(fixture_cfg(), &path, decode_b, 8).unwrap()
}

/// ISSUE 2 satellite: with `recall_floor >= 1.0` (shadow mode; all-ones
/// mask for the always-enforcing `Static`) every policy variant produces
/// token-for-token identical output to host dense decode.
#[test]
fn shadow_mode_matches_dense_for_every_policy_and_arch() {
    for arch in ["opt", "llama", "falcon"] {
        let prompt: Vec<u32> = vec![5, 9, 13, 21];
        let n = 12usize;
        let mut dense = engine(arch, EngineConfig::default());
        dense.submit(prompt.clone(), n);
        let want = dense.run_to_completion().unwrap().remove(0).tokens;
        assert_eq!(want.len(), n);

        let c = cfg(arch);
        let policies: Vec<(&str, NeuronPolicy)> = vec![
            ("dense", NeuronPolicy::Dense),
            (
                "static(ones)",
                NeuronPolicy::Static(Tensor::ones_f32(vec![c.n_layers, c.d_ff])),
            ),
            ("reuse", NeuronPolicy::Reuse { window: 3, union_k: 3 }),
            ("topp", NeuronPolicy::TopP { window: 3, budget: 0.9 }),
        ];
        for (name, policy) in policies {
            let is_static = matches!(policy, NeuronPolicy::Static(_));
            let is_predictive = policy.is_predictive();
            let ecfg = EngineConfig {
                policy,
                recall_floor: 1.0,
                ..EngineConfig::default()
            };
            let mut e = engine(arch, ecfg);
            e.submit(prompt.clone(), n);
            let got = e.run_to_completion().unwrap().remove(0).tokens;
            assert_eq!(got, want, "{arch}/{name}: shadow mode changed tokens");
            if is_static {
                // all-ones mask is enforced but cannot change anything
                assert!(e.metrics.enforced_steps > 0, "{arch}/{name}");
            } else {
                assert_eq!(e.metrics.enforced_steps, 0, "{arch}/{name}");
            }
            if is_predictive {
                assert!(
                    !e.metrics.predictor_recall.is_empty(),
                    "{arch}/{name}: shadow recall was never measured"
                );
            }
        }
    }
}

/// An enforcing predictive policy must still complete, with sane counters —
/// and a sub-1.0 floor on a stable stream must actually enforce.
#[test]
fn enforcing_reuse_completes_with_sparse_steps() {
    for arch in ["opt", "llama", "falcon"] {
        let ecfg = EngineConfig {
            policy: NeuronPolicy::Reuse { window: 2, union_k: 2 },
            recall_floor: 0.05,
            probe_every: 4,
            ..EngineConfig::default()
        };
        let mut e = engine(arch, ecfg);
        e.submit(vec![2, 4, 8], 12);
        let done = e.run_to_completion().unwrap();
        assert_eq!(done[0].tokens.len(), 12, "{arch}");
        assert!(e.metrics.enforced_steps > 0, "{arch}: nothing was enforced");
        assert!(e.metrics.probe_steps > 0, "{arch}: probes never ran");
        let density = e.metrics.mask_density.mean();
        assert!(
            density > 0.0 && density <= 1.0,
            "{arch}: bad mask density {density}"
        );
    }
}

/// Same prompt in every slot of one batch must decode identically — the
/// host attention/KV indexing cannot leak across rows.
#[test]
fn batch_rows_decode_independently() {
    let mut e = engine("opt", EngineConfig::default());
    let prompt: Vec<u32> = vec![7, 3, 11];
    for _ in 0..2 {
        e.submit(prompt.clone(), 10);
    }
    let mut done = e.run_to_completion().unwrap();
    done.sort_by_key(|d| d.id);
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].tokens, done[1].tokens, "batch rows interfered");
    // and a fresh engine reproduces the run (host backend is deterministic)
    let mut e2 = engine("opt", EngineConfig::default());
    e2.submit(prompt, 10);
    assert_eq!(e2.run_to_completion().unwrap()[0].tokens, done[0].tokens);
}

/// Prefill over T tokens and the equivalent prefill-then-decode chain are
/// BIT-identical on the host backend: per-token math is sequential f32, so
/// causality bugs, KV ordering bugs or position mix-ups show up exactly.
#[test]
fn decode_chain_is_bit_identical_to_prefill() {
    for arch in ["opt", "llama", "falcon"] {
        let be = HostBackend::random(cfg(arch), 7, 1, 8).unwrap();
        let doc: Vec<i32> = vec![2, 7, 1, 8, 4, 9, 6, 3];
        let full = be
            .prefill(&Tensor::i32(vec![1, 8], doc.clone()).unwrap())
            .unwrap();
        let flog = full.logits.as_f32().unwrap();
        let v = be.config().vocab;

        // chain: prefill the first 4 tokens (padded), then decode the rest
        let mut padded = doc.clone();
        for p in padded.iter_mut().skip(4) {
            *p = 0;
        }
        let pre = be.prefill(&Tensor::i32(vec![1, 8], padded).unwrap()).unwrap();
        let plog = pre.logits.as_f32().unwrap();
        for g in 0..4 {
            assert_eq!(
                &plog[g * v..(g + 1) * v],
                &flog[g * v..(g + 1) * v],
                "{arch}: padding leaked into causal position {g}"
            );
        }
        let mut kv = pre.kv.clone();
        let mask = Tensor::ones_f32(vec![be.config().n_layers, be.config().d_ff]);
        for g in 4..8 {
            let out = be
                .decode(
                    &kv,
                    &Tensor::i32(vec![1], vec![g as i32]).unwrap(),
                    &Tensor::i32(vec![1, 1], vec![doc[g]]).unwrap(),
                    &mask,
                )
                .unwrap();
            kv = out.kv;
            assert_eq!(
                out.logits.as_f32().unwrap(),
                &flog[g * v..(g + 1) * v],
                "{arch}: decode at position {g} diverged from prefill"
            );
        }
        assert_eq!(
            kv.as_f32().unwrap(),
            full.kv.as_f32().unwrap(),
            "{arch}: final chain KV differs from prefill KV"
        );
    }
}

/// ISSUE 2 satellite: the committed golden fixture. Greedy decode of the
/// checkpoint under the host backend must reproduce the token IDs computed
/// by the L2 JAX reference (tools/make_host_fixture.py; every argmax is
/// decided by a margin ~4 orders of magnitude above f32 noise).
#[test]
fn golden_fixture_greedy_tokens_are_pinned() {
    let backend = fixture_backend(2);
    assert_eq!(backend.model_id(), "fixture_opt_relu_s0");
    let mut e = Engine::new(Box::new(backend), EngineConfig::default()).unwrap();
    e.submit(vec![3, 1, 4, 1, 5], 10);
    let done = e.run_to_completion().unwrap();
    assert_eq!(
        done[0].tokens,
        vec![27, 1, 32, 32, 32, 28, 28, 39, 39, 39],
        "golden greedy decode drifted from the L2 reference"
    );
    assert_eq!(e.metrics.tokens_generated, 10);
    assert_eq!(e.metrics.enforced_steps, 0);
}

/// Golden fixture, part 2: the predictor counter schedule under an
/// enforcing Reuse policy is fully deterministic — window 2 and
/// probe_every 4 over 12 decode steps give probes at steps {0, 4, 8},
/// warmup/dense at {1, 2}, and enforcement at the remaining 7 steps, with
/// exactly one shadow recall measurement per probe-adjacent dense step
/// ({2, 4, 8}).
#[test]
fn golden_fixture_pins_recall_and_density_counters() {
    let backend = fixture_backend(2);
    let ecfg = EngineConfig {
        policy: NeuronPolicy::Reuse { window: 2, union_k: 2 },
        recall_floor: 0.05, // tiny floor: enforcement gated only by warmup
        probe_every: 4,
        ..EngineConfig::default()
    };
    let mut e = Engine::new(Box::new(backend), ecfg).unwrap();
    e.submit(vec![3, 1, 4, 1, 5], 12);
    let done = e.run_to_completion().unwrap();
    assert_eq!(done[0].tokens.len(), 12);
    assert_eq!(e.metrics.steps, 12);
    assert_eq!(e.metrics.probe_steps, 3, "probes at steps 0, 4, 8");
    assert_eq!(
        e.metrics.enforced_steps, 7,
        "enforced at steps 3, 5-7, 9-11"
    );
    assert_eq!(
        e.metrics.predictor_recall.len(),
        3,
        "one shadow eval per measurable dense step (2, 4, 8)"
    );
    assert_eq!(e.metrics.fallback_events, 0);
    assert_eq!(e.metrics.mask_density.len(), 7);
    let density = e.metrics.mask_density.mean();
    assert!(
        density > 0.0 && density < 1.0,
        "enforced masks must be sparse, got density {density}"
    );
    for i in 0..=10 {
        let r = e.metrics.predictor_recall.percentile(10.0 * i as f64);
        assert!((0.0..=1.0).contains(&r), "recall {r} out of range");
    }
}

/// The JSON-lines TCP server end-to-end over the host backend — the whole
/// serving stack with no PJRT anywhere in the process.
#[test]
fn server_roundtrip_over_host_backend() {
    use std::sync::mpsc;
    let (ready_tx, ready_rx) = mpsc::channel();
    let bpe = Arc::new(rsb::tokenizer::Bpe::train("ab ab ab ba baab abba", 24).unwrap());
    let bpe_srv = bpe.clone();
    let server = std::thread::spawn(move || {
        let backend = HostBackend::random(cfg("opt"), 0, 2, 6).unwrap();
        let ecfg = EngineConfig {
            policy: NeuronPolicy::Reuse { window: 4, union_k: 4 },
            recall_floor: 1.0,
            ..EngineConfig::default()
        };
        let engine = Engine::new(Box::new(backend), ecfg).unwrap();
        rsb::server::serve(engine, bpe_srv, "127.0.0.1:0", Some(2), Some(ready_tx))
    });
    let addr = ready_rx
        .recv_timeout(std::time::Duration::from_secs(60))
        .expect("server start");
    let mut client = rsb::server::Client::connect(addr).unwrap();
    // a malformed line first: the error path must not wedge the engine
    client.send_line("{\"id\": 3, \"max_tokens\": 2}").unwrap();
    let resp = client.recv().unwrap();
    assert!(resp
        .get("error")
        .and_then(|v| v.as_str())
        .unwrap()
        .contains("prompt"));
    for i in 0..2 {
        let resp = client.request(i, "ab ba", 4, 0.0).unwrap();
        assert_eq!(resp.get("id").and_then(|v| v.as_i64()), Some(i as i64));
        assert_eq!(resp.get("tokens").and_then(|v| v.as_usize()), Some(4));
        assert!(resp.get("text").is_some());
    }
    assert_eq!(server.join().unwrap().unwrap(), 2);
}

/// Sampling still behaves on the host backend (temperature diverges seeds).
#[test]
fn sampling_diverges_across_seeds() {
    let mut e = engine("opt", EngineConfig::default());
    let prompt = vec![4, 2, 4, 2];
    for seed in [1, 2] {
        e.submit_with(
            prompt.clone(),
            12,
            SamplingParams {
                temperature: 1.5,
                top_k: 0,
                seed,
            },
        );
    }
    let mut done = e.run_to_completion().unwrap();
    done.sort_by_key(|d| d.id);
    assert_ne!(
        done[0].tokens, done[1].tokens,
        "different seeds at T=1.5 should diverge"
    );
}
