//! Untrusted-input hardening: corrupt and truncated checkpoint files must
//! fail with a clean `Error::Checkpoint` — never a panic, an arithmetic
//! overflow, or an unbounded allocation — for BOTH container formats:
//!
//! - classic `RSBCKPT1` tensor checkpoints (`runtime::checkpoint::load`):
//!   truncated payloads, dims larger than the remaining file, `u64`
//!   overflow shapes, zero-length dims, absurd tensor counts, unknown
//!   dtype codes, non-utf8 names;
//! - `RSBTIER1` tiered FFN weight files (`runtime::tiered::TieredStore`):
//!   bad magic/version, zero or absurd geometry, bad gated/page fields,
//!   section offsets past end-of-file, truncation at every section.
//!
//! CI additionally runs this suite in release with
//! `-C debug-assertions=on`, so any checked-arithmetic regression that
//! would silently wrap in a normal release build aborts loudly here.

use std::path::{Path, PathBuf};

use rsb::error::Error;
use rsb::runtime::checkpoint;
use rsb::runtime::tiered::{self, TieredMeta, TieredStore};
use rsb::runtime::Tensor;

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("rsb_corrupt_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Every hostile input must surface as `Error::Checkpoint` specifically:
/// an `Io` leak means a read raced past a bounds check, a panic means the
/// header was trusted somewhere.
fn assert_checkpoint_err<T>(what: &str, r: rsb::Result<T>) {
    match r {
        Err(Error::Checkpoint(msg)) => {
            assert!(!msg.is_empty(), "{what}: empty Checkpoint message")
        }
        Err(e) => panic!("{what}: expected Error::Checkpoint, got {e:?}"),
        Ok(_) => panic!("{what}: expected Error::Checkpoint, got Ok"),
    }
}

fn push_u32(v: &mut Vec<u8>, x: u32) {
    v.extend_from_slice(&x.to_le_bytes());
}

fn push_u64(v: &mut Vec<u8>, x: u64) {
    v.extend_from_slice(&x.to_le_bytes());
}

/// `RSBCKPT1` magic + caller-built body.
fn classic_file(dir: &Path, name: &str, build: impl FnOnce(&mut Vec<u8>)) -> PathBuf {
    let mut bytes = b"RSBCKPT1".to_vec();
    build(&mut bytes);
    let path = dir.join(name);
    std::fs::write(&path, &bytes).unwrap();
    path
}

/// One well-formed header entry for tensor `a` (dtype f32), dims chosen by
/// the caller, NO payload bytes appended.
fn classic_entry(v: &mut Vec<u8>, dims: &[u64]) {
    push_u32(v, 1); // n_tensors
    push_u32(v, 1); // name_len
    v.push(b'a');
    v.push(0); // dtype f32
    push_u32(v, dims.len() as u32);
    for &d in dims {
        push_u64(v, d);
    }
}

#[test]
fn classic_rejects_truncated_payload() {
    let dir = tmpdir("classic_trunc");
    let path = dir.join("ok.ckpt");
    let t = Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
    checkpoint::save(&path, &[("a".into(), &t)]).unwrap();
    checkpoint::load(&path).unwrap(); // sanity: intact file loads

    let full = std::fs::read(&path).unwrap();
    // cut mid-payload and at every header boundary down to the bare magic
    for keep in [full.len() - 4, full.len() - 20, 30, 13, 12, 9, 8, 3] {
        let cut = dir.join(format!("cut_{keep}.ckpt"));
        std::fs::write(&cut, &full[..keep]).unwrap();
        assert_checkpoint_err(
            &format!("classic truncated to {keep} bytes"),
            checkpoint::load(&cut),
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn classic_rejects_dims_past_remaining_bytes() {
    let dir = tmpdir("classic_dims");
    // a ~40-byte file declaring a 4 GiB tensor: must be rejected by the
    // remaining-length bound, not by attempting the allocation
    let path = classic_file(&dir, "big.ckpt", |v| classic_entry(v, &[1 << 30]));
    assert_checkpoint_err("declared 4 GiB payload", checkpoint::load(&path));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn classic_rejects_overflowing_shapes() {
    let dir = tmpdir("classic_overflow");
    // numel = u64::MAX * 2 overflows the element-count accumulator
    let p1 = classic_file(&dir, "numel.ckpt", |v| classic_entry(v, &[u64::MAX, 2]));
    assert_checkpoint_err("numel overflow", checkpoint::load(&p1));
    // numel fits but numel * 4 (payload bytes) overflows
    let p2 = classic_file(&dir, "payload.ckpt", |v| classic_entry(v, &[1 << 62]));
    assert_checkpoint_err("payload-length overflow", checkpoint::load(&p2));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn classic_rejects_zero_dims_and_absurd_headers() {
    let dir = tmpdir("classic_hdr");
    let zero = classic_file(&dir, "zero.ckpt", |v| classic_entry(v, &[4, 0]));
    assert_checkpoint_err("zero-length dimension", checkpoint::load(&zero));

    let count = classic_file(&dir, "count.ckpt", |v| push_u32(v, u32::MAX));
    assert_checkpoint_err("absurd tensor count", checkpoint::load(&count));

    let rank = classic_file(&dir, "rank.ckpt", |v| {
        push_u32(v, 1);
        push_u32(v, 1);
        v.push(b'a');
        v.push(0);
        push_u32(v, 17); // rank cap is 16
    });
    assert_checkpoint_err("absurd rank", checkpoint::load(&rank));

    let name = classic_file(&dir, "name.ckpt", |v| {
        push_u32(v, 1);
        push_u32(v, u32::MAX); // name longer than the file
    });
    assert_checkpoint_err("absurd name length", checkpoint::load(&name));

    let utf8 = classic_file(&dir, "utf8.ckpt", |v| {
        push_u32(v, 1);
        push_u32(v, 1);
        v.push(0xff); // not utf-8
        v.push(0);
        push_u32(v, 0);
    });
    assert_checkpoint_err("non-utf8 name", checkpoint::load(&utf8));

    let dtype = classic_file(&dir, "dtype.ckpt", |v| {
        push_u32(v, 1);
        push_u32(v, 1);
        v.push(b'a');
        v.push(9); // unknown dtype code
        push_u32(v, 1);
        push_u64(v, 1);
        push_u32(v, 0); // 4 payload bytes
    });
    assert_checkpoint_err("unknown dtype", checkpoint::load(&dtype));

    let magic = dir.join("magic.ckpt");
    std::fs::write(&magic, b"NOTRIGHT____").unwrap();
    assert_checkpoint_err("bad magic", checkpoint::load(&magic));
    std::fs::remove_dir_all(&dir).ok();
}

/// A small valid `RSBTIER1` file (2 layers, d 4, f 8, non-gated).
fn valid_tier(path: &Path) {
    let meta = TieredMeta {
        n_layers: 2,
        d: 4,
        f: 8,
        gated: false,
    };
    let biases = vec![vec![0.25f32; 8]; 2];
    let brefs: Vec<&[f32]> = biases.iter().map(|b| b.as_slice()).collect();
    tiered::write_tiered(path, &meta, &brefs, None, &mut |l, j, rec| {
        for (k, v) in rec.iter_mut().enumerate() {
            *v = (l * 1000 + j * 100 + k) as f32;
        }
    })
    .unwrap();
}

/// Copy the valid tier file, let the caller damage the bytes, return the
/// damaged path.
fn corrupt_tier(dir: &Path, name: &str, damage: impl FnOnce(&mut Vec<u8>)) -> PathBuf {
    let src = dir.join("valid.tier");
    if !src.exists() {
        valid_tier(&src);
    }
    let mut bytes = std::fs::read(&src).unwrap();
    damage(&mut bytes);
    let path = dir.join(name);
    std::fs::write(&path, &bytes).unwrap();
    path
}

#[test]
fn tiered_rejects_corrupt_headers() {
    let dir = tmpdir("tier_hdr");
    // sanity: the pristine file opens and reports sane stats
    let src = dir.join("valid.tier");
    valid_tier(&src);
    let store = TieredStore::open(&src, 1 << 20, 0).unwrap();
    assert_eq!(store.stats().cold_misses, 0);
    drop(store);

    let cases: Vec<(&str, PathBuf)> = vec![
        (
            "bad magic",
            corrupt_tier(&dir, "magic.tier", |b| b[0] = b'X'),
        ),
        (
            "unsupported version",
            corrupt_tier(&dir, "version.tier", |b| b[8..12].copy_from_slice(&9u32.to_le_bytes())),
        ),
        (
            "zero layers",
            corrupt_tier(&dir, "layers.tier", |b| b[12..16].fill(0)),
        ),
        (
            "absurd width",
            corrupt_tier(&dir, "width.tier", |b| {
                b[20..24].copy_from_slice(&u32::MAX.to_le_bytes())
            }),
        ),
        (
            "bad gated flag",
            corrupt_tier(&dir, "gated.tier", |b| {
                b[24..28].copy_from_slice(&7u32.to_le_bytes())
            }),
        ),
        (
            "bad page alignment",
            corrupt_tier(&dir, "page.tier", |b| b[28..32].fill(0)),
        ),
        (
            "bias section past eof",
            corrupt_tier(&dir, "bias.tier", |b| {
                b[32..40].copy_from_slice(&u64::MAX.to_le_bytes())
            }),
        ),
        (
            "freq section past eof",
            corrupt_tier(&dir, "freq.tier", |b| {
                b[40..48].copy_from_slice(&(1u64 << 60).to_le_bytes())
            }),
        ),
        (
            "cold block past eof",
            corrupt_tier(&dir, "cold.tier", |b| {
                b[48..56].copy_from_slice(&u64::MAX.to_le_bytes())
            }),
        ),
    ];
    for (what, path) in cases {
        assert_checkpoint_err(what, TieredStore::open(&path, 1 << 20, 0));
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tiered_rejects_truncated_files() {
    let dir = tmpdir("tier_trunc");
    let src = dir.join("valid.tier");
    valid_tier(&src);
    let full = std::fs::read(&src).unwrap();
    // cut inside the cold blocks, the sections, the offsets and the magic
    for keep in [full.len() / 2, 100, 63, 48, 40, 32, 12, 8, 3, 0] {
        let cut = dir.join(format!("cut_{keep}.tier"));
        std::fs::write(&cut, &full[..keep]).unwrap();
        assert_checkpoint_err(
            &format!("tiered truncated to {keep} bytes"),
            TieredStore::open(&cut, 1 << 20, 0),
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn tiered_overflow_geometry_cannot_wrap() {
    let dir = tmpdir("tier_overflow");
    // geometry at the caps: l * f * 4 and f * rec_bytes stay in checked
    // u64 arithmetic; with DIM_CAP = 1 << 20 on every axis the section
    // lengths exceed any real file long before they could overflow, so
    // the failure must be the bounds check — not a wrap or an OOM
    let path = corrupt_tier(&dir, "caps.tier", |b| {
        for off in [12, 16, 20] {
            b[off..off + 4].copy_from_slice(&(1u32 << 20).to_le_bytes());
        }
    });
    assert_checkpoint_err("cap-sized geometry", TieredStore::open(&path, 1 << 20, 0));
    std::fs::remove_dir_all(&dir).ok();
}
