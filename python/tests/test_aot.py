"""AOT artifact integrity: manifests agree with the model, HLO text is
rust-loadable (no custom-calls), entry IO order is exactly reproducible."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

CFG = M.make_config("tiny", "opt", "relu", 0)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    aot.build_model(CFG, out, ("init", "score", "decode1"), verbose=False)
    return out


def _manifest(built):
    with open(os.path.join(built, CFG.model_id, "manifest.json")) as f:
        return json.load(f)


def test_manifest_params_match_model(built):
    man = _manifest(built)
    specs = M.param_specs(CFG)
    assert man["param_count"] == M.param_count(CFG)
    assert len(man["params"]) == len(specs)
    for rec, (name, shape) in zip(man["params"], specs):
        assert rec["name"] == name
        assert tuple(rec["shape"]) == tuple(shape)


def test_manifest_entry_io(built):
    man = _manifest(built)
    n = len(M.param_specs(CFG))
    init = man["entries"]["init"]
    assert [i["name"] for i in init["inputs"]] == ["seed"]
    assert len(init["outputs"]) == n
    score = man["entries"]["score"]
    assert len(score["inputs"]) == n + 1
    assert score["inputs"][-1]["dtype"] == "i32"
    b = man["buckets"]
    assert score["inputs"][-1]["shape"] == [b["score_b"], b["train_t"] + 1]
    assert score["outputs"][0]["shape"] == [b["score_b"], b["train_t"]]
    dec = man["entries"]["decode1"]
    assert dec["inputs"][n]["shape"] == list(M.kv_shape(CFG, 1))
    assert dec["outputs"][1]["shape"] == list(M.kv_shape(CFG, 1))


def test_hlo_text_is_rust_loadable(built):
    """No custom-calls (the CPU PJRT plugin can't run Mosaic/callbacks) and
    an ENTRY computation must be present."""
    mdir = os.path.join(built, CFG.model_id)
    man = _manifest(built)
    for name, ent in man["entries"].items():
        text = open(os.path.join(mdir, ent["file"])).read()
        assert "custom-call" not in text, name
        assert "ENTRY" in text, name
        # every declared input appears as a parameter
        assert text.count("parameter(") >= len(ent["inputs"]), name


def test_entry_param_ordering_roundtrip(built):
    """Feeding init outputs positionally into score reproduces in-process
    numerics — guarantees the rust runtime's positional marshalling is
    faithful."""
    params = M.init_params(CFG, 123)
    man = _manifest(built)
    b = man["buckets"]
    toks = (np.arange(b["score_b"] * (b["train_t"] + 1), dtype=np.int32)
            .reshape(b["score_b"], b["train_t"] + 1) % CFG.vocab)
    nll, st = M.score_tokens(CFG, params, jnp.asarray(toks))
    assert nll.shape == (b["score_b"], b["train_t"])
    assert np.isfinite(np.asarray(nll)).all()
    assert 0.0 <= float(st.min()) and float(st.max()) <= 1.0


def test_grid_ids_are_unique():
    ids = [f"{s}_{a}_{c}_s{st}" for (s, a, c, st, _, _) in aot.GRID]
    assert len(ids) == len(set(ids))


def test_init_is_deterministic():
    a = M.init_params(CFG, 42)
    b = M.init_params(CFG, 42)
    c = M.init_params(CFG, 43)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    diff = sum(float(jnp.sum(jnp.abs(x - y))) for x, y in zip(a, c))
    assert diff > 0.0
