"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, activations and mask densities; assert_allclose
against ref.py is the contract that lets the L2 model use the kernel on the
serve path and the oracle on the autodiff path interchangeably.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.activations import ACT_NAMES
from compile.kernels import ref
from compile.kernels.ffn import ffn_pallas, gated_ffn_pallas, pick_tile, vmem_bytes
from compile.kernels.matvec import masked_matvec_pallas

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(key, *shape, scale=0.25):
    return scale * jax.random.normal(key, shape, jnp.float32)


def _mask(key, f, density):
    return (jax.random.uniform(key, (f,)) < density).astype(jnp.float32)


@st.composite
def ffn_shapes(draw):
    bt = draw(st.sampled_from([1, 2, 3, 4, 8, 24, 64]))
    d = draw(st.sampled_from([4, 8, 16, 32]))
    f = draw(st.sampled_from([4, 16, 48, 64, 96, 256]))
    act = draw(st.sampled_from(ACT_NAMES))
    density = draw(st.sampled_from([0.0, 0.3, 1.0]))
    seed = draw(st.integers(0, 2**31 - 1))
    return bt, d, f, act, density, seed


@given(ffn_shapes())
@settings(**SETTINGS)
def test_ffn_matches_ref(params):
    bt, d, f, act, density, seed = params
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 5)
    x = _rand(ks[0], bt, d, scale=1.0)
    wu, bu, wd = _rand(ks[1], d, f), _rand(ks[2], f), _rand(ks[3], f, d)
    m = _mask(ks[4], f, density)
    out, pre = ffn_pallas(x, wu, bu, wd, m, act)
    out_r, pre_r = ref.ffn_ref(x, wu, bu, wd, m, act)
    np.testing.assert_allclose(out, out_r, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(pre, pre_r, rtol=3e-5, atol=3e-5)


@given(ffn_shapes())
@settings(**SETTINGS)
def test_gated_ffn_matches_ref(params):
    bt, d, f, act, density, seed = params
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 5)
    x = _rand(ks[0], bt, d, scale=1.0)
    wg, wu, wd = _rand(ks[1], d, f), _rand(ks[2], d, f), _rand(ks[3], f, d)
    m = _mask(ks[4], f, density)
    out, pre = gated_ffn_pallas(x, wg, wu, wd, m, act)
    out_r, pre_r = ref.gated_ffn_ref(x, wg, wu, wd, m, act)
    np.testing.assert_allclose(out, out_r, rtol=3e-5, atol=3e-5)
    np.testing.assert_allclose(pre, pre_r, rtol=3e-5, atol=3e-5)


@given(st.sampled_from([4, 16, 48, 256]), st.sampled_from([4, 16, 32]),
       st.sampled_from([0.0, 0.1, 0.5, 1.0]), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_matvec_matches_ref(f, d, density, seed):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 3)
    w, a = _rand(ks[0], f, d), _rand(ks[1], f, scale=1.0)
    m = _mask(ks[2], f, density)
    y = masked_matvec_pallas(w, a, m)
    np.testing.assert_allclose(y, ref.masked_matvec_ref(w, a, m),
                               rtol=3e-5, atol=3e-5)


def test_zero_mask_kills_output():
    """All-dead neuron mask => FFN output is exactly zero (the row-skip
    guarantee the rust cost model relies on)."""
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 4)
    x = _rand(ks[0], 8, 16, scale=1.0)
    wu, bu, wd = _rand(ks[1], 16, 64), _rand(ks[2], 64), _rand(ks[3], 64, 16)
    out, _ = ffn_pallas(x, wu, bu, wd, jnp.zeros((64,)), "gelu")
    np.testing.assert_array_equal(np.asarray(out), 0.0)


def test_mask_is_row_structured():
    """Masking neuron j is equivalent to zeroing row j of w_down."""
    k = jax.random.PRNGKey(1)
    ks = jax.random.split(k, 5)
    x = _rand(ks[0], 4, 8, scale=1.0)
    wu, bu, wd = _rand(ks[1], 8, 32), _rand(ks[2], 32), _rand(ks[3], 32, 8)
    m = _mask(ks[4], 32, 0.5)
    out_masked, _ = ffn_pallas(x, wu, bu, wd, m, "relu")
    wd_zeroed = wd * m[:, None]
    out_rows, _ = ffn_pallas(x, wu, bu, wd_zeroed, jnp.ones((32,)), "relu")
    np.testing.assert_allclose(out_masked, out_rows, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n,expected", [(128, 128), (96, 32), (7, 7), (1, 1),
                                        (384, 128), (24, 8)])
def test_pick_tile(n, expected):
    assert pick_tile(n, (128, 64, 32, 16, 8, 7, 4, 2, 1)) == expected
    assert n % pick_tile(n, (128, 64, 32, 16, 8, 7, 4, 2, 1)) == 0


def test_vmem_budget():
    """The production tile choices stay under a 16MB VMEM budget (double
    buffered) — the §Perf L1 constraint from DESIGN.md."""
    for bt, bf, d in [(128, 256, 768), (128, 256, 256), (64, 128, 4096)]:
        assert vmem_bytes(bt, bf, d) < 16 * 2**20, (bt, bf, d)
