"""Hypothesis sweep over the incremental (KV-cache) forward path: random
batch sizes, chunk splits and positions must always agree with the
cache-free forward — this is the invariant the whole serving engine rests
on (decode ≡ prefill ≡ full, for every arch and relufication stage)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M

SETTINGS = dict(max_examples=10, deadline=None)


def _cfg(arch, act, stage):
    return M.make_config("tiny", arch, act, stage)


def _ones(cfg):
    return jnp.ones((cfg.n_layers, cfg.d_ff), jnp.float32)


@st.composite
def chunked_cases(draw):
    arch, act = draw(st.sampled_from(
        [("opt", "relu"), ("llama", "silu"), ("falcon", "gelu")]))
    stage = draw(st.sampled_from([0, 1, 2]))
    b = draw(st.integers(1, 3))
    t = draw(st.integers(4, 14))
    # random chunking of the t tokens into incremental calls
    cuts = sorted(draw(st.sets(st.integers(1, t - 1), max_size=3)))
    seed = draw(st.integers(0, 2**31 - 1))
    return arch, act, stage, b, t, cuts, seed


@given(chunked_cases())
@settings(**SETTINGS)
def test_chunked_incremental_matches_full(case):
    """Processing a sequence in arbitrary multi-token chunks through the KV
    cache reproduces the cache-free logits (covers prefill, decode AND
    verify shapes in one property)."""
    arch, act, stage, b, t, cuts, seed = case
    cfg = _cfg(arch, act, stage)
    ps = M.init_params(cfg, seed % 1000)
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0, cfg.vocab)
    want, _, _, _ = M.full_forward(cfg, ps, toks)

    kv = jnp.zeros(M.kv_shape(cfg, b), jnp.float32)
    bounds = [0] + cuts + [t]
    got = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        pos = jnp.full((b,), lo, jnp.int32)
        lg, kv, _, _ = M.incremental_forward(
            cfg, ps, toks[:, lo:hi], kv, pos, _ones(cfg))
        got.append(lg)
    got = jnp.concatenate(got, axis=1)
    np.testing.assert_allclose(want, got, rtol=6e-4, atol=6e-4)


@given(st.integers(0, 2**31 - 1), st.integers(1, 3))
@settings(**SETTINGS)
def test_staggered_rows_match_aligned(seed, extra):
    """Batch rows at different positions (continuous batching) produce the
    same logits as each row run alone at its own position."""
    cfg = _cfg("opt", "relu", 0)
    ps = M.init_params(cfg, 3)
    key = jax.random.PRNGKey(seed)
    t0, t1 = 4, 4 + extra
    s0 = jax.random.randint(jax.random.fold_in(key, 0), (1, t0), 0, cfg.vocab)
    s1 = jax.random.randint(jax.random.fold_in(key, 1), (1, t1), 0, cfg.vocab)
    nm = _ones(cfg)
    kv0 = jnp.zeros(M.kv_shape(cfg, 1), jnp.float32)
    kv1 = jnp.zeros(M.kv_shape(cfg, 1), jnp.float32)
    _, kv0, _, _ = M.incremental_forward(cfg, ps, s0, kv0, jnp.zeros((1,), jnp.int32), nm)
    _, kv1, _, _ = M.incremental_forward(cfg, ps, s1, kv1, jnp.zeros((1,), jnp.int32), nm)
    kvb = jnp.concatenate([kv0, kv1], axis=2)
    nxt = jax.random.randint(jax.random.fold_in(key, 2), (2, 1), 0, cfg.vocab)
    lgb, _, _, _ = M.incremental_forward(
        cfg, ps, nxt, kvb, jnp.array([t0, t1], jnp.int32), nm)
    la, _, _, _ = M.incremental_forward(
        cfg, ps, nxt[:1], kv0, jnp.array([t0], jnp.int32), nm)
    lb, _, _, _ = M.incremental_forward(
        cfg, ps, nxt[1:], kv1, jnp.array([t1], jnp.int32), nm)
    np.testing.assert_allclose(lgb[0], la[0], rtol=6e-4, atol=6e-4)
    np.testing.assert_allclose(lgb[1], lb[0], rtol=6e-4, atol=6e-4)


@given(st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_stale_kv_beyond_pos_is_ignored(seed):
    """The overwrite-before-attend invariant: garbage at positions >= pos
    must not influence logits (this is what makes speculative rollback and
    right-padded prefill sound)."""
    cfg = _cfg("llama", "silu", 0)
    ps = M.init_params(cfg, 5)
    key = jax.random.PRNGKey(seed)
    t = 6
    toks = jax.random.randint(jax.random.fold_in(key, 0), (1, t), 0, cfg.vocab)
    nm = _ones(cfg)
    kv_clean = jnp.zeros(M.kv_shape(cfg, 1), jnp.float32)
    _, kv_clean, _, _ = M.incremental_forward(
        cfg, ps, toks, kv_clean, jnp.zeros((1,), jnp.int32), nm)
    # poison everything at positions >= t
    poison = jax.random.normal(jax.random.fold_in(key, 1), kv_clean.shape) * 100.0
    mask = (jnp.arange(cfg.max_seq) >= t)[None, None, None, None, :, None]
    kv_dirty = jnp.where(mask, poison, kv_clean)
    nxt = jax.random.randint(jax.random.fold_in(key, 2), (1, 1), 0, cfg.vocab)
    pos = jnp.array([t], jnp.int32)
    a, _, _, _ = M.incremental_forward(cfg, ps, nxt, kv_clean, pos, nm)
    bb, _, _, _ = M.incremental_forward(cfg, ps, nxt, kv_dirty, pos, nm)
    np.testing.assert_allclose(a, bb, rtol=1e-5, atol=1e-5)


@given(st.integers(0, 2**31 - 1), st.floats(0.1, 0.9))
@settings(**SETTINGS)
def test_ffn_mask_union_semantics(seed, density):
    """incremental_forward's ffn_mask output is the union over the chunk's
    tokens and never exceeds the supplied neuron mask."""
    cfg = _cfg("opt", "relu", 0)
    ps = M.init_params(cfg, 7)
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(jax.random.fold_in(key, 0), (1, 5), 0, cfg.vocab)
    nm = (jax.random.uniform(jax.random.fold_in(key, 1),
                             (cfg.n_layers, cfg.d_ff)) < density).astype(jnp.float32)
    kv = jnp.zeros(M.kv_shape(cfg, 1), jnp.float32)
    _, _, fm_all, _ = M.incremental_forward(
        cfg, ps, toks, kv, jnp.zeros((1,), jnp.int32), nm)
    assert float(jnp.max(fm_all * (1.0 - nm[:, None, :]))) == 0.0
    # union property: processing token-by-token and OR-ing equals the chunk mask
    kv = jnp.zeros(M.kv_shape(cfg, 1), jnp.float32)
    acc = jnp.zeros_like(fm_all)
    for i in range(5):
        _, kv, fm, _ = M.incremental_forward(
            cfg, ps, toks[:, i:i + 1], kv, jnp.array([i], jnp.int32), nm)
        acc = jnp.maximum(acc, fm)
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(fm_all))
