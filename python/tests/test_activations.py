"""Activation zoo semantics (paper Fig 2a/2b, §5.3)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.activations import ACT_NAMES, apply_act, act_zero_mask, beta_silu

GRID = jnp.linspace(-5.0, 5.0, 401)


def test_silu_is_beta_one():
    np.testing.assert_allclose(apply_act("silu", GRID), beta_silu(GRID, 1.0))


def test_gelu_matches_jax():
    np.testing.assert_allclose(apply_act("gelu", GRID),
                               jax.nn.gelu(GRID, approximate=True),
                               rtol=1e-5, atol=1e-5)


def test_beta_inf_approaches_relu():
    """Fig 2a: increasing beta sweeps SiLU -> ReLU."""
    relu = apply_act("relu", GRID)
    for beta, tol in [(1.0, 1.0), (8.0, 0.1), (64.0, 0.02), (512.0, 0.01)]:
        err = float(jnp.max(jnp.abs(beta_silu(GRID, beta) - relu)))
        assert err < tol, (beta, err)


def test_sparsity_ordering_on_gaussian():
    """Paper Fig 2c: sparsity(silu) < sparsity(bsilu8) <= sparsity(relu)
    < sparsity(shifted relu) on N(0,1) preactivations."""
    x = jax.random.normal(jax.random.PRNGKey(0), (100_000,))
    frac = {a: float(1.0 - act_zero_mask(a, apply_act(a, x)).mean())
            for a in ACT_NAMES}
    assert frac["silu"] < 1e-5  # smooth gates never hit exact zero
    assert frac["gelu"] < 1e-5
    assert abs(frac["relu"] - 0.5) < 0.01
    assert frac["srelu"] > frac["relu"]  # ReLU(x-1) drops ~84% of N(0,1)
    assert abs(frac["srelu"] - 0.841) < 0.01


def test_shifted_relu_cutoff():
    """ReLU(x - b) zeroes exactly x <= b."""
    y = apply_act("srelu", GRID, shift=1.0)
    np.testing.assert_array_equal(np.asarray(y[GRID <= 1.0]), 0.0)
    assert np.all(np.asarray(y[GRID > 1.0]) > 0.0)


@given(st.floats(-50, 50), st.sampled_from(ACT_NAMES))
@settings(max_examples=60, deadline=None)
def test_acts_finite_and_lower_bounded(x, act):
    y = float(apply_act(act, jnp.float32(x)))
    assert np.isfinite(y)
    if act in ("relu", "srelu"):
        assert y >= 0.0
    else:
        assert y >= -0.5  # silu/gelu minimum is > -0.3


def test_fig2b_tail_ordering():
    """Fig 2b: on moderately negative preactivations SiLU passes the most
    mass, GELU less, beta=8 less still, ReLU none."""
    x = jnp.float32(-2.0)
    mags = {a: abs(float(apply_act(a, x))) for a in ("silu", "gelu", "bsilu8", "relu")}
    assert mags["silu"] > mags["gelu"] > mags["bsilu8"] > mags["relu"] == 0.0
