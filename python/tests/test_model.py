"""L2 model invariants: path agreement, KV-cache correctness, relufication
semantics, optimizer behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

ARCH_ACT = [("opt", "relu"), ("llama", "silu"), ("falcon", "gelu")]


def _cfg(arch="opt", act="relu", stage=0, **kw):
    return M.make_config("tiny", arch, act, stage, **kw)


def _toks(cfg, b, t, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0, cfg.vocab)


def _ones_mask(cfg):
    return jnp.ones((cfg.n_layers, cfg.d_ff), jnp.float32)


@pytest.mark.parametrize("arch,act", ARCH_ACT)
@pytest.mark.parametrize("stage", [0, 1, 2])
def test_prefill_matches_full(arch, act, stage):
    cfg = _cfg(arch, act, stage)
    ps = M.init_params(cfg, 0)
    toks = _toks(cfg, 2, 10)
    logits, _, _, _ = M.full_forward(cfg, ps, toks)
    kv = jnp.zeros(M.kv_shape(cfg, 2), jnp.float32)
    lg, _, _, _ = M.incremental_forward(cfg, ps, toks, kv,
                                        jnp.zeros((2,), jnp.int32), _ones_mask(cfg))
    np.testing.assert_allclose(logits, lg, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("arch,act", ARCH_ACT)
def test_decode_chain_matches_full(arch, act):
    """Token-by-token decode over the KV cache reproduces the cache-free
    forward — the core serving-correctness invariant."""
    cfg = _cfg(arch, act, 0)
    ps = M.init_params(cfg, 1)
    t = 9
    toks = _toks(cfg, 1, t, seed=3)
    ref_logits, _, _, _ = M.full_forward(cfg, ps, toks)
    kv = jnp.zeros(M.kv_shape(cfg, 1), jnp.float32)
    nm = _ones_mask(cfg)
    for i in range(t):
        lg, kv, _, _ = M.incremental_forward(
            cfg, ps, toks[:, i:i + 1], kv,
            jnp.array([i], jnp.int32), nm)
        np.testing.assert_allclose(ref_logits[:, i], lg[:, 0],
                                   rtol=5e-4, atol=5e-4, err_msg=f"pos {i}")


def test_verify_matches_decode_chain():
    """Multi-token verify (speculative decoding) == sequential decode."""
    cfg = _cfg("opt", "relu")
    ps = M.init_params(cfg, 2)
    g = 4
    prompt = _toks(cfg, 1, 6, seed=5)
    draft = _toks(cfg, 1, g, seed=6)
    nm = _ones_mask(cfg)
    kv0 = jnp.zeros(M.kv_shape(cfg, 1), jnp.float32)
    _, kv0, _, _ = M.incremental_forward(cfg, ps, prompt, kv0,
                                         jnp.zeros((1,), jnp.int32), nm)
    # path A: verify all gamma tokens at once
    lg_v, _, _, _ = M.incremental_forward(cfg, ps, draft, kv0,
                                          jnp.array([6], jnp.int32), nm)
    # path B: decode one at a time
    kv = kv0
    for i in range(g):
        lg_d, kv, _, _ = M.incremental_forward(
            cfg, ps, draft[:, i:i + 1], kv, jnp.array([6 + i], jnp.int32), nm)
        np.testing.assert_allclose(lg_v[:, i], lg_d[:, 0], rtol=5e-4, atol=5e-4)


def test_per_row_positions_are_independent():
    """Rows of a decode batch at different positions don't interfere."""
    cfg = _cfg("llama", "silu")
    ps = M.init_params(cfg, 4)
    nm = _ones_mask(cfg)
    t1, t2 = 5, 8
    s1, s2 = _toks(cfg, 1, t1, seed=7), _toks(cfg, 1, t2, seed=8)
    # batched: row0 = s1, row1 = s2 (prefilled separately, packed manually)
    kvb = jnp.zeros(M.kv_shape(cfg, 2), jnp.float32)
    kv1 = jnp.zeros(M.kv_shape(cfg, 1), jnp.float32)
    kv2 = jnp.zeros(M.kv_shape(cfg, 1), jnp.float32)
    _, kv1, _, _ = M.incremental_forward(cfg, ps, s1, kv1, jnp.zeros((1,), jnp.int32), nm)
    _, kv2, _, _ = M.incremental_forward(cfg, ps, s2, kv2, jnp.zeros((1,), jnp.int32), nm)
    kvb = kvb.at[:, :, 0:1].set(kv1).at[:, :, 1:2].set(kv2)
    nxt = jnp.array([[1], [2]], jnp.int32)
    lgb, _, _, _ = M.incremental_forward(cfg, ps, nxt, kvb,
                                         jnp.array([t1, t2], jnp.int32), nm)
    lg1, _, _, _ = M.incremental_forward(cfg, ps, nxt[:1], kv1,
                                         jnp.array([t1], jnp.int32), nm)
    lg2, _, _, _ = M.incremental_forward(cfg, ps, nxt[1:], kv2,
                                         jnp.array([t2], jnp.int32), nm)
    np.testing.assert_allclose(lgb[0], lg1[0], rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(lgb[1], lg2[0], rtol=5e-4, atol=5e-4)


def test_neuron_mask_semantics():
    """Masked-out neurons (a) force ffn_mask to 0 and (b) change the output
    exactly as zeroing the down-projection rows would (paper §5.1)."""
    cfg = _cfg("opt", "relu")
    ps = M.init_params(cfg, 0)
    toks = _toks(cfg, 1, 4)
    kv = jnp.zeros(M.kv_shape(cfg, 1), jnp.float32)
    pos = jnp.zeros((1,), jnp.int32)
    key = jax.random.PRNGKey(9)
    nm = (jax.random.uniform(key, (cfg.n_layers, cfg.d_ff)) < 0.5).astype(jnp.float32)
    _, _, fm, _ = M.incremental_forward(cfg, ps, toks, kv, pos, nm)
    assert float(jnp.max(fm * (1.0 - nm[:, None, :]))) == 0.0
    # masked fwd == fwd with down-proj rows zeroed
    names = [n for n, _ in M.param_specs(cfg)]
    ps_zeroed = list(ps)
    for l in range(cfg.n_layers):
        i = names.index(f"l{l}.ffn.w_down")
        ps_zeroed[i] = ps_zeroed[i] * nm[l][:, None]
    lg_m, _, _, _ = M.incremental_forward(cfg, ps, toks, kv, pos, nm)
    lg_z, _, _, _ = M.incremental_forward(cfg, tuple(ps_zeroed), toks, kv, pos,
                                          _ones_mask(cfg))
    np.testing.assert_allclose(lg_m, lg_z, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("arch,act", ARCH_ACT)
def test_stage2_sparsifies_qkv_and_up(arch, act):
    """Stage-2 surgery makes QKV/up-projection inputs sparse (paper §4.2);
    stage-0 smooth-activation models have ~0 sparsity everywhere."""
    cfg0 = _cfg(arch, act, 0)
    cfg2 = _cfg(arch, act, 2)
    ps = M.init_params(cfg0, 3)  # same param shapes across stages
    toks = _toks(cfg0, 2, 16, seed=11)
    _, st0, _, _ = M.full_forward(cfg0, ps, toks)
    _, st2, _, _ = M.full_forward(cfg2, ps, toks)
    assert float(st0[:, 0].max()) < 0.05  # qkv dense at stage 0
    assert float(st2[:, 0].mean()) > 0.25  # ReLU-after-norm sparsifies
    assert float(st2[:, 1].mean()) > 0.25
    # ffn sparsity at stage>=1 is ReLU-level even for silu/gelu models
    assert float(st2[:, 2].mean()) > 0.25


def test_sparsity_stats_bounds():
    cfg = _cfg("llama", "srelu", 1, shift=1.0)
    ps = M.init_params(cfg, 5)
    _, st, _, _ = M.full_forward(cfg, ps, _toks(cfg, 2, 12, seed=13))
    assert float(st.min()) >= 0.0 and float(st.max()) <= 1.0
    # shifted ReLU must be sparser than the N(0,sigma) half-mass
    assert float(st[:, 2].mean()) > 0.6


def test_param_specs_order_and_count():
    for arch, act in ARCH_ACT:
        cfg = _cfg(arch, act)
        specs = M.param_specs(cfg)
        names = [n for n, _ in specs]
        assert len(names) == len(set(names))
        assert names[0] == "embed"
        flat = M.init_params(cfg, 0)
        assert len(flat) == len(specs)
        for (n, s), arr in zip(specs, flat):
            assert tuple(arr.shape) == tuple(s), n
        assert M.param_count(cfg) == sum(int(np.prod(s)) for _, s in specs)


def test_train_k_reduces_loss():
    """A few steps on a repeated batch must drive loss down (the end-to-end
    learning signal the trainer relies on)."""
    cfg = _cfg("opt", "relu")
    ps = M.init_params(cfg, 7)
    m = [jnp.zeros_like(p) for p in ps]
    v = [jnp.zeros_like(p) for p in ps]
    k, b, t = 4, 2, 16
    one = _toks(cfg, b, t + 1, seed=17)
    toks = jnp.broadcast_to(one, (k, b, t + 1))
    lrs = jnp.full((k,), 3e-3, jnp.float32)
    n = len(ps)
    step = jnp.float32(0)
    first = last = None
    for it in range(4):
        out = M.train_k_steps(cfg, ps, m, v, step, lrs, toks)
        ps, m, v = out[:n], out[n:2 * n], out[2 * n:3 * n]
        losses = out[-2]
        gnorms = out[-1]
        assert np.all(np.isfinite(np.asarray(losses)))
        assert np.all(np.asarray(gnorms) > 0)
        step = step + k
        if first is None:
            first = float(losses[0])
        last = float(losses[-1])
    assert last < first - 0.5, (first, last)


def test_score_matches_manual_ce():
    cfg = _cfg("falcon", "gelu")
    ps = M.init_params(cfg, 8)
    toks = _toks(cfg, 2, 13, seed=19)
    nll, _ = M.score_tokens(cfg, ps, toks)
    logits, _, _, _ = M.full_forward(cfg, ps, toks[:, :-1],
                                     use_pallas=cfg.use_pallas)
    logp = jax.nn.log_softmax(logits, -1)
    want = -np.take_along_axis(np.asarray(logp),
                               np.asarray(toks[:, 1:])[..., None], -1)[..., 0]
    np.testing.assert_allclose(nll, want, rtol=1e-5, atol=1e-5)


def test_probe_shapes_and_histogram_mass():
    cfg = _cfg("llama", "silu")
    ps = M.init_params(cfg, 9)
    t = 12
    pre, st, logit_mean = M.probe_tokens(cfg, ps, _toks(cfg, 1, t, seed=23))
    assert pre.shape == (cfg.n_layers, t, cfg.d_ff)
    assert np.all(np.isfinite(np.asarray(pre)))
    # logit_mean keeps the LM head live in the lowered HLO (param pruning
    # guard) and must be finite
    assert np.isfinite(float(logit_mean))
    assert st.shape == (cfg.n_layers, 3)


def test_pallas_and_oracle_paths_agree():
    """use_pallas=True (serve path) and False (train path) produce identical
    logits — the L1<->L2 seam."""
    for arch, act in ARCH_ACT:
        cfg = _cfg(arch, act, 2)
        ps = M.init_params(cfg, 10)
        toks = _toks(cfg, 2, 8, seed=29)
        a, _, _, _ = M.full_forward(cfg, ps, toks, use_pallas=True)
        b, _, _, _ = M.full_forward(cfg, ps, toks, use_pallas=False)
        np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)
