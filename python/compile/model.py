"""L2: the JAX model zoo that gets AOT-lowered to HLO text.

Three decoder-only architecture families, mirroring the paper's subjects
(§3.1) at laptop scale (DESIGN.md §3 substitutions):

  opt    — LayerNorm (+bias), learned absolute positions, FFN with biases,
           native activation ReLU (the paper's "already sparse" family).
  llama  — RMSNorm, RoPE, gated SwiGLU FFN, native activation SiLU.
  falcon — LayerNorm, RoPE, parallel attention/FFN block sharing one norm,
           native activation GELU.

Relufication stages (paper §4, Fig 3):
  stage 0 — native activation.
  stage 1 — FFN activation (gate activation for llama) replaced with ReLU.
  stage 2 — stage 1 + ReLU inserted after the norm(s) feeding QKV and the
            FFN up/gate projections.

Every entry point takes the parameters as leading positional arrays in the
exact order of `param_specs(cfg)`; the AOT manifest records that order so the
rust runtime can marshal checkpoints without re-deriving pytree structure.

Paths:
  full_forward        — no KV cache; train_k (autodiff => jnp FFN oracle),
                        score, probe.
  incremental_forward — KV cache + per-row positions; prefill (G=T),
                        decode (G=1), verify (G=gamma). Uses the L1 Pallas
                        FFN kernel on this serve path.
The two paths share norms/attention math; python/tests/test_model.py checks
decode/prefill agreement against full_forward token by token.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .activations import apply_act
from .kernels import ref as kref
from .kernels.ffn import ffn_pallas, gated_ffn_pallas

ARCHS = ("opt", "llama", "falcon")
NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    size: str
    arch: str  # opt | llama | falcon
    act: str  # relu | gelu | silu | bsilu8 | srelu
    stage: int  # 0 | 1 | 2
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    vocab: int
    max_seq: int
    shift: float = 1.0  # srelu's b
    use_pallas: bool = True  # L1 kernel on the serve path

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def ffn_act(self) -> str:
        """Effective FFN activation after relufication surgery."""
        return "relu" if (self.stage >= 1 and self.act not in ("srelu",)) else self.act

    @property
    def model_id(self) -> str:
        return f"{self.size}_{self.arch}_{self.act}_s{self.stage}"

    @property
    def gated(self) -> bool:
        return self.arch == "llama"

    @property
    def parallel_block(self) -> bool:
        return self.arch == "falcon"

    @property
    def has_bias(self) -> bool:
        return self.arch == "opt"


#: size -> (d_model, n_layers, n_heads, d_ff, vocab, max_seq)
SIZES: Dict[str, Tuple[int, int, int, int, int, int]] = {
    "tiny": (64, 2, 2, 256, 256, 64),
    "small": (128, 4, 4, 512, 512, 96),
    # draft: small geometry, base vocabulary (speculative-decoding M_q must
    # share the target's tokenizer)
    "draft": (128, 4, 4, 512, 2048, 96),
    "base": (256, 6, 8, 1024, 2048, 96),
    "e2e100m": (768, 12, 12, 3072, 8192, 96),
}


def make_config(size: str, arch: str, act: str, stage: int, shift: float = 1.0,
                use_pallas: bool = True) -> ModelConfig:
    d, l, h, f, v, t = SIZES[size]
    return ModelConfig(size=size, arch=arch, act=act, stage=stage, d_model=d,
                       n_layers=l, n_heads=h, d_ff=f, vocab=v, max_seq=t,
                       shift=shift, use_pallas=use_pallas)


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) list; flatten order == entry-point arg order."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    specs: List[Tuple[str, Tuple[int, ...]]] = [("embed", (v, d))]
    if cfg.arch == "opt":
        specs.append(("pos_embed", (cfg.max_seq, d)))
    for l in range(cfg.n_layers):
        p = f"l{l}."
        specs.append((p + "ln1.scale", (d,)))
        if cfg.arch != "llama":
            specs.append((p + "ln1.bias", (d,)))
        specs.append((p + "attn.wqkv", (d, 3 * d)))
        specs.append((p + "attn.wo", (d, d)))
        if not cfg.parallel_block:  # falcon shares ln1 across attn + ffn
            specs.append((p + "ln2.scale", (d,)))
            if cfg.arch != "llama":
                specs.append((p + "ln2.bias", (d,)))
        if cfg.gated:
            specs.append((p + "ffn.w_gate", (d, f)))
        specs.append((p + "ffn.w_up", (d, f)))
        if cfg.has_bias:
            specs.append((p + "ffn.b_up", (f,)))
        specs.append((p + "ffn.w_down", (f, d)))
        if cfg.has_bias:
            specs.append((p + "ffn.b_down", (d,)))
    specs.append(("lnf.scale", (d,)))
    if cfg.arch != "llama":
        specs.append(("lnf.bias", (d,)))
    return specs


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))


def init_params(cfg: ModelConfig, seed) -> Tuple[jnp.ndarray, ...]:
    """GPT-2 style init: N(0, 0.02), residual projections scaled 1/sqrt(2L)."""
    key = jax.random.PRNGKey(jnp.asarray(seed, dtype=jnp.uint32))
    out = []
    resid_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    for i, (name, shape) in enumerate(param_specs(cfg)):
        k = jax.random.fold_in(key, i)
        if name.endswith(".scale"):
            arr = jnp.ones(shape, jnp.float32)
        elif name.endswith(".bias") or name.startswith("l") and ".b_" in name:
            arr = jnp.zeros(shape, jnp.float32)
        elif name.endswith("attn.wo") or name.endswith("ffn.w_down"):
            arr = 0.02 * resid_scale * jax.random.normal(k, shape, jnp.float32)
        else:
            arr = 0.02 * jax.random.normal(k, shape, jnp.float32)
        out.append(arr)
    return tuple(out)


class Params:
    """Name-indexed view over the flat parameter tuple."""

    def __init__(self, cfg: ModelConfig, flat: Sequence[jnp.ndarray]):
        self._names = [n for n, _ in param_specs(cfg)]
        assert len(flat) == len(self._names), (len(flat), len(self._names))
        self._by_name = dict(zip(self._names, flat))

    def __getitem__(self, name: str) -> jnp.ndarray:
        return self._by_name[name]

    def __contains__(self, name: str) -> bool:
        return name in self._by_name


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------

def _norm(cfg: ModelConfig, x, scale, bias):
    if cfg.arch == "llama":  # RMSNorm
        var = jnp.mean(x * x, axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(var + 1e-5) * scale
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def _rope(x, pos_ids):
    """x: [B, G, H, hd]; pos_ids: [B, G]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos_ids[..., None].astype(jnp.float32) * freqs  # [B, G, half]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _zero_frac(x) -> jnp.ndarray:
    return jnp.mean((x == 0.0).astype(jnp.float32))


def _ffn_apply(cfg: ModelConfig, params: Params, l: int, x2d, neuron_mask_l,
               use_pallas: bool):
    """Run layer `l`'s FFN on [BT, d] tokens.

    Returns (out [BT, d], act_mask [BT, F], preact [BT, F]).
    act_mask marks FFN activations that are exactly zero-free — the paper's
    down-projection row liveness (Fig 1b).
    """
    p = f"l{l}.ffn."
    act, shift = cfg.ffn_act, cfg.shift
    if cfg.gated:
        wg, wu, wd = params[p + "w_gate"], params[p + "w_up"], params[p + "w_down"]
        if use_pallas:
            out, preact = gated_ffn_pallas(x2d, wg, wu, wd, neuron_mask_l, act, shift)
        else:
            out, preact = kref.gated_ffn_ref(x2d, wg, wu, wd, neuron_mask_l, act, shift)
        gate_val = apply_act(act, preact, shift) * neuron_mask_l
        act_mask = (gate_val != 0.0).astype(jnp.float32)
        return out, act_mask, preact
    wu, wd = params[p + "w_up"], params[p + "w_down"]
    bu = params[p + "b_up"] if cfg.has_bias else jnp.zeros((cfg.d_ff,), jnp.float32)
    if use_pallas:
        out, preact = ffn_pallas(x2d, wu, bu, wd, neuron_mask_l, act, shift)
    else:
        out, preact = kref.ffn_ref(x2d, wu, bu, wd, neuron_mask_l, act, shift)
    if cfg.has_bias:
        out = out + params[p + "b_down"]
    act_val = apply_act(act, preact, shift) * neuron_mask_l
    act_mask = (act_val != 0.0).astype(jnp.float32)
    return out, act_mask, preact


def _attention(cfg: ModelConfig, q, k, v, allowed):
    """q: [B,H,G,hd]; k,v: [B,H,S,hd]; allowed: [B,1,G,S] bool."""
    scale = 1.0 / jnp.sqrt(float(cfg.head_dim))
    scores = jnp.einsum("bhgd,bhsd->bhgs", q, k) * scale
    scores = jnp.where(allowed, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgs,bhsd->bhgd", probs, v)
    return out


def _split_heads(cfg: ModelConfig, x):  # [B,G,d] -> [B,G,H,hd]
    b, g, _ = x.shape
    return x.reshape(b, g, cfg.n_heads, cfg.head_dim)


def _merge_heads(cfg: ModelConfig, x):  # [B,H,G,hd] -> [B,G,d]
    b, h, g, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, g, h * hd)


# --------------------------------------------------------------------------
# Full (cache-free) forward — train / score / probe
# --------------------------------------------------------------------------

def full_forward(cfg: ModelConfig, flat_params, tokens, use_pallas: bool = False):
    """tokens: i32[B, T]. Returns (logits [B,T,V], sparsity [L,3],
    preacts [L, B, T, F], ffn_masks [L, B, T, F])."""
    params = Params(cfg, flat_params)
    b, t = tokens.shape
    x = params["embed"][tokens]  # [B,T,d]
    pos_ids = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    if cfg.arch == "opt":
        x = x + params["pos_embed"][:t][None, :, :]
    allowed = (jnp.arange(t)[None, :] <= jnp.arange(t)[:, None])[None, None, :, :]
    ones_mask = jnp.ones((cfg.d_ff,), jnp.float32)

    stats, preacts, masks = [], [], []
    for l in range(cfg.n_layers):
        x, st, pa, am = _block(cfg, params, l, x, pos_ids, allowed, None, None,
                               ones_mask, use_pallas)
        stats.append(st)
        preacts.append(pa)
        masks.append(am)
    bias = params["lnf.bias"] if "lnf.bias" in params else None
    x = _norm(cfg, x, params["lnf.scale"], bias)
    logits = x @ params["embed"].T
    return (logits, jnp.stack(stats), jnp.stack(preacts), jnp.stack(masks))


def _block(cfg: ModelConfig, params: Params, l: int, x, pos_ids, allowed,
           kv, pos, neuron_mask_l, use_pallas):
    """One transformer block; works for both cache-free (kv=None) and
    incremental (kv = (kcache, vcache) for this layer) modes.

    Returns (x, stats [3], preact [B,G,F], act_mask [B,G,F]) plus, in
    incremental mode, the updated (kcache, vcache) via closure-free tuple —
    see _block_incremental wrapper below.
    """
    out = _block_inner(cfg, params, l, x, pos_ids, allowed, kv, pos,
                       neuron_mask_l, use_pallas)
    if kv is None:
        x, stats, preact, act_mask, _ = out
        return x, stats, preact, act_mask
    return out


def _block_inner(cfg, params, l, x, pos_ids, allowed, kv, pos, neuron_mask_l,
                 use_pallas):
    p = f"l{l}."
    b, g, d = x.shape
    bias1 = params[p + "ln1.bias"] if (p + "ln1.bias") in params else None
    h = _norm(cfg, x, params[p + "ln1.scale"], bias1)
    if cfg.stage >= 2:
        h = jnp.maximum(h, 0.0)  # ReLU after norm (paper Fig 3, stage 2)
    qkv_sparsity = _zero_frac(h)

    qkv = h @ params[p + "attn.wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q, k, v = (_split_heads(cfg, z) for z in (q, k, v))  # [B,G,H,hd]
    if cfg.arch != "opt":
        q = _rope(q, pos_ids)
        k = _rope(k, pos_ids)
    q = q.transpose(0, 2, 1, 3)  # [B,H,G,hd]
    k_t = k.transpose(0, 2, 1, 3)
    v_t = v.transpose(0, 2, 1, 3)

    if kv is None:
        k_ctx, v_ctx = k_t, v_t
        new_kv = None
    else:
        kcache, vcache = kv  # [B,H,Tmax,hd]

        def upd(cache_b, new_b, p_b):
            return jax.lax.dynamic_update_slice(cache_b, new_b, (0, p_b, 0))

        k_ctx = jax.vmap(upd)(kcache, k_t, pos)
        v_ctx = jax.vmap(upd)(vcache, v_t, pos)
        new_kv = (k_ctx, v_ctx)

    attn = _attention(cfg, q, k_ctx, v_ctx, allowed)
    attn_out = _merge_heads(cfg, attn) @ params[p + "attn.wo"]

    if cfg.parallel_block:
        ffn_in = h  # falcon: parallel attn/FFN sharing one norm
    else:
        x = x + attn_out
        bias2 = params[p + "ln2.bias"] if (p + "ln2.bias") in params else None
        ffn_in = _norm(cfg, x, params[p + "ln2.scale"], bias2)
        if cfg.stage >= 2:
            ffn_in = jnp.maximum(ffn_in, 0.0)
    up_sparsity = _zero_frac(ffn_in)

    ffn_out2d, act_mask2d, preact2d = _ffn_apply(
        cfg, params, l, ffn_in.reshape(b * g, d), neuron_mask_l, use_pallas)
    ffn_out = ffn_out2d.reshape(b, g, d)
    act_mask = act_mask2d.reshape(b, g, cfg.d_ff)
    preact = preact2d.reshape(b, g, cfg.d_ff)
    ffn_sparsity = 1.0 - jnp.mean(act_mask)

    if cfg.parallel_block:
        x = x + attn_out + ffn_out
    else:
        x = x + ffn_out
    stats = jnp.stack([qkv_sparsity, up_sparsity, ffn_sparsity])
    return x, stats, preact, act_mask, new_kv


# --------------------------------------------------------------------------
# Incremental forward — prefill / decode / verify
# --------------------------------------------------------------------------

def incremental_forward(cfg: ModelConfig, flat_params, tokens, kv, pos,
                        neuron_mask):
    """tokens: i32[B, G]; kv: f32[L,2,B,H,Tmax,hd]; pos: i32[B];
    neuron_mask: f32[L, F].

    Returns (logits [B,G,V], kv', ffn_mask [L,B,F], sparsity [L,3]).
    ffn_mask is the per-row union over the G processed tokens of live FFN
    activations — the quantity aggregated sparsity (§5.1) tracks.

    KV invariant: positions < pos[b] hold valid history for row b; this call
    writes positions pos[b] .. pos[b]+G-1 *before* attending to them, so any
    stale garbage beyond pos is never read (attention allows j <= pos+g).
    """
    params = Params(cfg, flat_params)
    b, g = tokens.shape
    tmax = kv.shape[4]
    pos_ids = pos[:, None] + jnp.arange(g, dtype=jnp.int32)[None, :]  # [B,G]
    x = params["embed"][tokens]
    if cfg.arch == "opt":
        x = x + params["pos_embed"][pos_ids]
    key_pos = jnp.arange(tmax, dtype=jnp.int32)
    allowed = key_pos[None, None, None, :] <= pos_ids[:, None, :, None]  # [B,1,G,Tmax]

    new_layers_k, new_layers_v, stats, masks = [], [], [], []
    for l in range(cfg.n_layers):
        x, st, _pa, am, new_kv = _block_inner(
            cfg, params, l, x, pos_ids, allowed, (kv[l, 0], kv[l, 1]), pos,
            neuron_mask[l], cfg.use_pallas)
        new_layers_k.append(new_kv[0])
        new_layers_v.append(new_kv[1])
        stats.append(st)
        masks.append(jnp.max(am, axis=1))  # union over G -> [B,F]
    bias = params["lnf.bias"] if "lnf.bias" in params else None
    x = _norm(cfg, x, params["lnf.scale"], bias)
    logits = x @ params["embed"].T  # [B,G,V]
    kv_out = jnp.stack(
        [jnp.stack([k, v]) for k, v in zip(new_layers_k, new_layers_v)])
    return logits, kv_out, jnp.stack(masks), jnp.stack(stats)


def kv_shape(cfg: ModelConfig, batch: int) -> Tuple[int, ...]:
    return (cfg.n_layers, 2, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim)


# --------------------------------------------------------------------------
# Loss / optimizer
# --------------------------------------------------------------------------

def _ce_loss(cfg: ModelConfig, flat_params, tokens):
    """tokens: i32[B, T+1]; returns mean next-token cross entropy."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits, _, _, _ = full_forward(cfg, flat_params, inputs, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def _decayable(name: str) -> bool:
    """AdamW weight decay applies to matrices only (not norms/biases)."""
    return ("wqkv" in name or "wo" in name or "w_up" in name
            or "w_gate" in name or "w_down" in name or "embed" in name)


def adamw_step(cfg: ModelConfig, flat_params, m, v, step, lr, tokens,
               b1=0.9, b2=0.95, eps=1e-8, wd=0.1, clip=1.0):
    """One AdamW update with global-norm clipping. step is f32 (1-based)."""
    names = [n for n, _ in param_specs(cfg)]
    loss, grads = jax.value_and_grad(lambda fp: _ce_loss(cfg, fp, tokens))(
        tuple(flat_params))
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads) + 1e-12)
    scale = jnp.minimum(1.0, clip / gnorm)
    grads = [g * scale for g in grads]
    new_p, new_m, new_v = [], [], []
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    for name, p, g, mi, vi in zip(names, flat_params, grads, m, v):
        mi = b1 * mi + (1.0 - b1) * g
        vi = b2 * vi + (1.0 - b2) * (g * g)
        upd = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
        if _decayable(name):
            upd = upd + wd * p
        new_p.append(p - lr * upd)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, loss, gnorm


def train_k_steps(cfg: ModelConfig, flat_params, m, v, step0, lrs, tokens_k):
    """K optimizer steps via lax.scan (amortizes the host<->device tuple
    roundtrip the rust runtime pays per execute).

    lrs: f32[K]; tokens_k: i32[K, B, T+1].
    Returns (params, m, v, losses [K], gnorms [K]).
    """
    n = len(flat_params)

    def body(carry, inp):
        ps, ms, vs, st = carry
        lr, toks = inp
        ps2, ms2, vs2, loss, gnorm = adamw_step(cfg, ps, ms, vs, st + 1.0, lr, toks)
        return (tuple(ps2), tuple(ms2), tuple(vs2), st + 1.0), (loss, gnorm)

    (ps, ms, vs, _), (losses, gnorms) = jax.lax.scan(
        body, (tuple(flat_params), tuple(m), tuple(v), step0), (lrs, tokens_k))
    return list(ps) + list(ms) + list(vs) + [losses, gnorms]


def score_tokens(cfg: ModelConfig, flat_params, tokens):
    """Teacher-forced per-token NLL. tokens: i32[B, T+1].

    Returns (nll [B, T], sparsity [L, 3]).
    """
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits, stats, _, _ = full_forward(cfg, flat_params, inputs,
                                       use_pallas=cfg.use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll, stats


def probe_tokens(cfg: ModelConfig, flat_params, tokens):
    """Preactivation capture (Fig 5 / 11 histograms, shifted-ReLU b fitting).

    tokens: i32[1, T] -> (preact [L, T, F], sparsity [L, 3], logit_mean []).

    logit_mean keeps the LM head (final norm + unembedding) live: jax.jit
    prunes unused parameters from the lowered HLO signature, which would
    desynchronize the manifest's positional input list from the compiled
    program (the rust runtime feeds ALL params positionally).
    """
    logits, stats, preacts, _ = full_forward(cfg, flat_params, tokens,
                                             use_pallas=cfg.use_pallas)
    return preacts[:, 0], stats, jnp.mean(logits)
