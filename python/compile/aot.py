"""AOT lowering: JAX entry points -> HLO text artifacts + JSON manifest.

This is the ONLY place python touches the pipeline; `make artifacts` runs it
once and the rust binary is self-contained afterwards.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts land in artifacts/<model_id>/{entry}.hlo.txt plus manifest.json
recording the exact positional input/output order of every entry point, the
canonical parameter flatten order, and the bucket constants the rust engine
must respect. artifacts/index.json lists all built model dirs.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

F32, I32, U32 = "f32", "i32", "u32"
_DTYPES = {F32: jnp.float32, I32: jnp.int32, U32: jnp.uint32}


def spec(dtype: str, shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), _DTYPES[dtype])


#: Per-size bucket constants (sequence/batch shapes baked into the HLO).
BUCKETS = {
    # train_k, train_b, train_t, score_b, prefill_t, decode_b, verify_g, probe_t
    "tiny": dict(train_k=4, train_b=4, train_t=32, score_b=4, prefill_t=16,
                 decode_b=4, verify_g=8, probe_t=32),
    "small": dict(train_k=8, train_b=8, train_t=64, score_b=8, prefill_t=48,
                  decode_b=4, verify_g=8, probe_t=64),
    "draft": dict(train_k=8, train_b=8, train_t=64, score_b=8, prefill_t=48,
                  decode_b=4, verify_g=8, probe_t=64),
    "base": dict(train_k=8, train_b=8, train_t=64, score_b=8, prefill_t=48,
                 decode_b=4, verify_g=8, probe_t=64),
    "e2e100m": dict(train_k=2, train_b=4, train_t=64, score_b=4, prefill_t=48,
                    decode_b=2, verify_g=8, probe_t=64),
}


def to_hlo_text(lowered, expect_params: int = None) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True: every entry
    returns a tuple; the rust side unwraps with decompose_tuple).

    `expect_params` guards against jax.jit pruning unused arguments from the
    lowered signature — that would silently desynchronize the manifest's
    positional input list from the compiled program.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    if expect_params is not None:
        got = len(comp.program_shape().parameter_shapes())
        if got != expect_params:
            raise RuntimeError(
                f"entry lowered with {got} parameters but manifest declares "
                f"{expect_params}: some inputs are unused and were pruned — "
                "make the entry depend on every input (see probe_tokens)")
    return comp.as_hlo_text()


def _param_io(cfg: M.ModelConfig, prefix: str) -> List[dict]:
    return [{"name": f"{prefix}{n}", "dtype": F32, "shape": list(s)}
            for n, s in M.param_specs(cfg)]


def build_entries(cfg: M.ModelConfig) -> Dict[str, Tuple]:
    """entry name -> (callable, input descriptors, output descriptors).

    Input descriptors are positional: the rust runtime feeds literals in
    exactly this order and receives the output tuple in exactly the output
    order. Names are documentation + checkpoint keys.
    """
    b = BUCKETS[cfg.size]
    L, Fd, V, d = cfg.n_layers, cfg.d_ff, cfg.vocab, cfg.d_model
    n_params = len(M.param_specs(cfg))
    pio = _param_io(cfg, "param:")
    k, tb, tt = b["train_k"], b["train_b"], b["train_t"]
    sb, pt = b["score_b"], b["prefill_t"]
    db, vg, prt = b["decode_b"], b["verify_g"], b["probe_t"]
    kvs = lambda bb: list(M.kv_shape(cfg, bb))

    def io(name, dtype, shape):
        return {"name": name, "dtype": dtype, "shape": list(shape)}

    entries = {}

    entries["init"] = (
        lambda seed: M.init_params(cfg, seed),
        [io("seed", U32, ())],
        pio,
    )

    def train_k(*args):
        p = args[:n_params]
        m = args[n_params:2 * n_params]
        v = args[2 * n_params:3 * n_params]
        step, lrs, toks = args[3 * n_params:]
        return tuple(M.train_k_steps(cfg, p, m, v, step, lrs, toks))

    entries["train_k"] = (
        train_k,
        pio + _param_io(cfg, "m:") + _param_io(cfg, "v:")
        + [io("step", F32, ()), io("lrs", F32, (k,)),
           io("tokens", I32, (k, tb, tt + 1))],
        pio + _param_io(cfg, "m:") + _param_io(cfg, "v:")
        + [io("losses", F32, (k,)), io("gnorms", F32, (k,))],
    )

    def score(*args):
        return M.score_tokens(cfg, args[:n_params], args[n_params])

    entries["score"] = (
        score,
        pio + [io("tokens", I32, (sb, tt + 1))],
        [io("nll", F32, (sb, tt)), io("sparsity", F32, (L, 3))],
    )

    def prefill(*args):
        p, toks = args[:n_params], args[n_params]
        kv = jnp.zeros(M.kv_shape(cfg, 1), jnp.float32)
        pos = jnp.zeros((1,), jnp.int32)
        nm = jnp.ones((L, Fd), jnp.float32)
        logits, kv2, fm, st = M.incremental_forward(cfg, p, toks, kv, pos, nm)
        return logits, kv2, fm, st

    entries["prefill"] = (
        prefill,
        pio + [io("tokens", I32, (1, pt))],
        [io("logits", F32, (1, pt, V)), io("kv", F32, kvs(1)),
         io("ffn_mask", F32, (L, 1, Fd)), io("sparsity", F32, (L, 3))],
    )

    def make_decode(bb, g):
        def fn(*args):
            p = args[:n_params]
            kv, pos, toks, nm = args[n_params:]
            return M.incremental_forward(cfg, p, toks, kv, pos, nm)

        return (
            fn,
            pio + [io("kv", F32, kvs(bb)), io("pos", I32, (bb,)),
                   io("tokens", I32, (bb, g)), io("neuron_mask", F32, (L, Fd))],
            [io("logits", F32, (bb, g, V)), io("kv", F32, kvs(bb)),
             io("ffn_mask", F32, (L, bb, Fd)), io("sparsity", F32, (L, 3))],
        )

    entries["decode"] = make_decode(db, 1)
    entries["decode1"] = make_decode(1, 1)
    entries["verify"] = make_decode(1, vg)

    def probe(*args):
        return M.probe_tokens(cfg, args[:n_params], args[n_params])

    entries["probe"] = (
        probe,
        pio + [io("tokens", I32, (1, prt))],
        [io("preact", F32, (L, prt, Fd)), io("sparsity", F32, (L, 3)),
         io("logit_mean", F32, ())],
    )

    return entries


def lower_entry(fn, inputs) -> str:
    args = [spec(i["dtype"], i["shape"]) for i in inputs]
    return to_hlo_text(jax.jit(fn).lower(*args), expect_params=len(inputs))


def build_model(cfg: M.ModelConfig, out_dir: str, entry_names, verbose=True):
    mdir = os.path.join(out_dir, cfg.model_id)
    os.makedirs(mdir, exist_ok=True)
    entries = build_entries(cfg)
    manifest = {
        "model_id": cfg.model_id,
        "config": {
            "size": cfg.size, "arch": cfg.arch, "act": cfg.act,
            "stage": cfg.stage, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "vocab": cfg.vocab, "max_seq": cfg.max_seq,
            "shift": cfg.shift, "use_pallas": cfg.use_pallas,
            "ffn_act": cfg.ffn_act, "gated": cfg.gated,
            "parallel_block": cfg.parallel_block, "has_bias": cfg.has_bias,
        },
        "param_count": int(M.param_count(cfg)),
        "params": [{"name": n, "shape": list(s)} for n, s in M.param_specs(cfg)],
        "buckets": BUCKETS[cfg.size],
        "entries": {},
    }
    for name in entry_names:
        fn, inputs, outputs = entries[name]
        t0 = time.time()
        text = lower_entry(fn, inputs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(mdir, fname), "w") as f:
            f.write(text)
        manifest["entries"][name] = {
            "file": fname, "inputs": inputs, "outputs": outputs,
        }
        if verbose:
            print(f"  {cfg.model_id}/{name}: {len(text)/1e6:.2f}MB "
                  f"({time.time()-t0:.1f}s)", flush=True)
    with open(os.path.join(mdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return mdir


ALL_ENTRIES = ("init", "train_k", "score", "prefill", "decode", "decode1",
               "verify", "probe")
TRAIN_ONLY = ("init", "train_k", "score", "probe")

#: The default grid `make artifacts` builds: (size, arch, act, stage, shift,
#: entries). See DESIGN.md §5 for which experiment consumes which id.
GRID = [
    # tests + quickstart
    ("tiny", "opt", "relu", 0, 1.0, ALL_ENTRIES),
    # draft model for speculative decoding + Fig 2 from-scratch sweep
    ("small", "opt", "relu", 0, 1.0, ALL_ENTRIES),
    ("small", "opt", "gelu", 0, 1.0, TRAIN_ONLY),
    ("small", "opt", "silu", 0, 1.0, TRAIN_ONLY),
    ("small", "opt", "bsilu8", 0, 1.0, TRAIN_ONLY),
    # speculative-decoding draft model (base vocab)
    ("draft", "opt", "relu", 0, 1.0, ALL_ENTRIES),
    # main experiment grid (Table 1/2, Figs 1, 4-8)
    ("base", "opt", "relu", 0, 1.0, ALL_ENTRIES),
    ("base", "opt", "relu", 2, 1.0, ALL_ENTRIES),
    ("base", "llama", "silu", 0, 1.0, ALL_ENTRIES),
    ("base", "llama", "relu", 1, 1.0, ALL_ENTRIES),
    ("base", "llama", "relu", 2, 1.0, ALL_ENTRIES),
    ("base", "llama", "srelu", 1, 1.0, ALL_ENTRIES),
    ("base", "llama", "gelu", 0, 1.0, TRAIN_ONLY),  # Table 2 activation swap
    ("base", "falcon", "gelu", 0, 1.0, ALL_ENTRIES),
    ("base", "falcon", "relu", 1, 1.0, ALL_ENTRIES),
    ("base", "falcon", "relu", 2, 1.0, ALL_ENTRIES),
    ("base", "falcon", "silu", 0, 1.0, TRAIN_ONLY),  # Table 2 activation swap
    # end-to-end ~100M driver (examples/e2e_pipeline.rs)
    ("e2e100m", "opt", "relu", 0, 1.0,
     ("init", "train_k", "score", "prefill", "decode1")),
]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="comma-separated model_id filter (substring match)")
    ap.add_argument("--size", default=None)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--act", default=None)
    ap.add_argument("--stage", type=int, default=None)
    ap.add_argument("--shift", type=float, default=None)
    ap.add_argument("--entries", default=None)
    ap.add_argument("--no-pallas", action="store_true",
                    help="use the jnp oracle FFN on the serve path too")
    args = ap.parse_args()

    if args.size:  # single ad-hoc model
        grid = [(args.size, args.arch or "opt", args.act or "relu",
                 args.stage or 0, args.shift or 1.0,
                 tuple((args.entries or ",".join(ALL_ENTRIES)).split(",")))]
    else:
        grid = GRID
        if args.only:
            keys = args.only.split(",")
            grid = [g for g in grid
                    if any(k in f"{g[0]}_{g[1]}_{g[2]}_s{g[3]}" for k in keys)]
        if args.entries:
            ent = tuple(args.entries.split(","))
            grid = [(s, a, c, st, sh, ent) for (s, a, c, st, sh, _) in grid]

    os.makedirs(args.out_dir, exist_ok=True)
    built = []
    t0 = time.time()
    for size, arch, act, stage, shift, entry_names in grid:
        cfg = M.make_config(size, arch, act, stage, shift,
                            use_pallas=not args.no_pallas)
        print(f"[aot] {cfg.model_id} ({M.param_count(cfg)/1e6:.2f}M params)",
              flush=True)
        build_model(cfg, args.out_dir, entry_names)
        built.append(cfg.model_id)
    index_path = os.path.join(args.out_dir, "index.json")
    existing = []
    if os.path.exists(index_path):
        with open(index_path) as f:
            existing = json.load(f).get("models", [])
    models = sorted(set(existing) | set(built))
    with open(index_path, "w") as f:
        json.dump({"models": models}, f, indent=1)
    print(f"[aot] built {len(built)} model dirs in {time.time()-t0:.0f}s "
          f"-> {args.out_dir}")


if __name__ == "__main__":
    main()
