"""L1 Pallas kernels: fused, neuron-masked FFN.

The paper's compute hot-spot is the FFN pair (up-projection -> activation ->
down-projection); its efficiency claim is that a zero activation kills an
entire *row* of the down-projection (weight transfer + MACs, Fig 1b / 9a).

TPU mapping (DESIGN.md §Hardware-Adaptation): instead of the paper's
GPU-threadblock row skipping we tile the hidden dimension F into BF-sized
blocks. Each grid step stages one [d, BF] up-projection tile and one [BF, d]
down-projection tile HBM->VMEM via BlockSpec (the unit of "row transfer"),
applies the activation + neuron mask in VMEM, and accumulates the partial
down-projection into a revisited [BT, d] output block. Matmul shapes
([BT, d] x [d, BF] and [BT, BF] x [BF, d]) feed the MXU systolic array.

Kernels are lowered with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); the lowered HLO is a fori-loop over the grid with dynamic
slices, which the rust runtime executes on the serve path.

Correctness oracle: kernels/ref.py, enforced by python/tests/test_kernels.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..activations import apply_act

#: Preferred token-tile / hidden-tile sizes, largest first. 128 matches the
#: MXU systolic edge; smaller fallbacks keep tiny test shapes legal.
_BT_CANDIDATES = (128, 64, 32, 16, 8, 4, 2, 1)
_BF_CANDIDATES = (256, 128, 64, 32, 16, 8, 4, 2, 1)


def pick_tile(n: int, candidates) -> int:
    """Largest candidate tile that divides `n` exactly."""
    for c in candidates:
        if n % c == 0:
            return c
    return 1


def vmem_bytes(bt: int, bf: int, d: int) -> int:
    """Estimated VMEM residency of one grid step (f32): x, w_up, b_up, w_down,
    mask tiles + out and preact accumulators. Used by DESIGN/EXPERIMENTS to
    check the double-buffered footprint against the ~16MB VMEM budget."""
    tiles = bt * d + d * bf + bf + bf * d + bf  # inputs
    accs = bt * d + bt * bf  # out + preact blocks
    return 4 * 2 * (tiles + accs)  # x2 for double buffering


def _ffn_kernel(x_ref, wu_ref, bu_ref, wd_ref, m_ref, o_ref, p_ref, *, act, shift, nf):
    """Grid = (n_token_tiles, n_hidden_tiles); hidden index j is minor, so the
    output block for a token tile is revisited across j and accumulated."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    preact = x_ref[...] @ wu_ref[...] + bu_ref[...]
    p_ref[...] = preact
    h = apply_act(act, preact, shift) * m_ref[...]
    o_ref[...] += h @ wd_ref[...]


def _gated_kernel(x_ref, wg_ref, wu_ref, wd_ref, m_ref, o_ref, p_ref, *, act, shift, nf):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    preact = x_ref[...] @ wg_ref[...]
    p_ref[...] = preact
    h = apply_act(act, preact, shift) * m_ref[...] * (x_ref[...] @ wu_ref[...])
    o_ref[...] += h @ wd_ref[...]


@functools.partial(jax.jit, static_argnames=("act", "shift"))
def ffn_pallas(x, w_up, b_up, w_down, neuron_mask, act: str, shift: float = 1.0):
    """Fused masked FFN; semantics of ref.ffn_ref.

    x [BT, d], w_up [d, F], b_up [F], w_down [F, d], neuron_mask [F]
    -> (out [BT, d], preact [BT, F]).
    """
    bt_total, d = x.shape
    f = w_up.shape[1]
    bt = pick_tile(bt_total, _BT_CANDIDATES)
    bf = pick_tile(f, _BF_CANDIDATES)
    nt, nf = bt_total // bt, f // bf

    out, preact = pl.pallas_call(
        functools.partial(_ffn_kernel, act=act, shift=shift, nf=nf),
        grid=(nt, nf),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),  # x: token tile
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),  # w_up column tile
            pl.BlockSpec((bf,), lambda i, j: (j,)),  # b_up tile
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),  # w_down row tile
            pl.BlockSpec((bf,), lambda i, j: (j,)),  # neuron mask tile
        ],
        out_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),  # out (revisited in j)
            pl.BlockSpec((bt, bf), lambda i, j: (i, j)),  # preact
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt_total, d), x.dtype),
            jax.ShapeDtypeStruct((bt_total, f), x.dtype),
        ],
        interpret=True,
    )(x, w_up, b_up, w_down, neuron_mask)
    return out, preact


@functools.partial(jax.jit, static_argnames=("act", "shift"))
def gated_ffn_pallas(x, w_gate, w_up, w_down, neuron_mask, act: str, shift: float = 1.0):
    """Fused masked gated FFN (SwiGLU family); semantics of ref.gated_ffn_ref."""
    bt_total, d = x.shape
    f = w_gate.shape[1]
    bt = pick_tile(bt_total, _BT_CANDIDATES)
    bf = pick_tile(f, _BF_CANDIDATES)
    nt, nf = bt_total // bt, f // bf

    out, preact = pl.pallas_call(
        functools.partial(_gated_kernel, act=act, shift=shift, nf=nf),
        grid=(nt, nf),
        in_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),  # w_gate
            pl.BlockSpec((d, bf), lambda i, j: (0, j)),  # w_up
            pl.BlockSpec((bf, d), lambda i, j: (j, 0)),  # w_down
            pl.BlockSpec((bf,), lambda i, j: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, bf), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bt_total, d), x.dtype),
            jax.ShapeDtypeStruct((bt_total, f), x.dtype),
        ],
        interpret=True,
    )(x, w_gate, w_up, w_down, neuron_mask)
    return out, preact
