"""L1 Pallas kernel: neuron-masked row matvec (paper Fig 9a at kernel level).

y = (a * mask) @ W for a single token's FFN activation vector `a` and the
down-projection W [F, d]. Tiles F into BF blocks; a block whose mask tile is
all-zero contributes nothing — the structural analogue of the paper's
"skip loading zeroed rows". On real TPU hardware the `@pl.when(live)` guard
elides both the MXU issue and (with a scalar-prefetched mask) the HBM->VMEM
copy of the W tile; under interpret=True it documents the schedule while the
rust substrate (rust/src/sparse) provides the measured row-skip latency.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ffn import pick_tile, _BF_CANDIDATES


def _kernel(a_ref, m_ref, w_ref, o_ref):
    j = pl.program_id(0)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    am = a_ref[...] * m_ref[...]
    live = jnp.any(am != 0.0)

    @pl.when(live)
    def _accum():
        # [1, BF] x [BF, d] on the MXU; skipped entirely for dead tiles.
        o_ref[...] += am[None, :] @ w_ref[...]


@jax.jit
def masked_matvec_pallas(w, a, mask):
    """Semantics of ref.masked_matvec_ref: (a * mask) @ w.

    w: [F, d], a: [F], mask: [F] -> y: [d].
    """
    f, d = w.shape
    bf = pick_tile(f, _BF_CANDIDATES)
    nf = f // bf

    out = pl.pallas_call(
        _kernel,
        grid=(nf,),
        in_specs=[
            pl.BlockSpec((bf,), lambda j: (j,)),  # a tile
            pl.BlockSpec((bf,), lambda j: (j,)),  # mask tile
            pl.BlockSpec((bf, d), lambda j: (j, 0)),  # W row tile
        ],
        out_specs=pl.BlockSpec((1, d), lambda j: (0, 0)),  # revisited accumulator
        out_shape=jax.ShapeDtypeStruct((1, d), w.dtype),
        interpret=True,
    )(a, mask, w)
    return out[0]
