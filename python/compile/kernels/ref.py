"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its oracle to float32 tolerance across the shape/dtype sweep in
python/tests/test_kernels.py (hypothesis). The L2 model also uses these
directly on paths where autodiff must flow (train/score), so kernel==ref
equality is what guarantees train-time and serve-time numerics agree.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..activations import apply_act


def ffn_ref(x, w_up, b_up, w_down, neuron_mask, act: str, shift: float = 1.0):
    """Non-gated FFN (OPT/Falcon style): down( mask * act(x @ w_up + b_up) ).

    Args:
      x:           [BT, d]  token activations.
      w_up:        [d, F]
      b_up:        [F]      (zeros when the architecture has no biases)
      w_down:      [F, d]
      neuron_mask: [F]      1.0 = neuron available, 0.0 = treat as unloaded
                   (the paper's §5.1 weight-reuse experiment).
      act:         activation name.

    Returns:
      (out [BT, d], preact [BT, F]).
      The FFN activation mask (paper's "down-projection input sparsity") is
      derived from `preact` by the caller: act(preact) * mask != 0.
    """
    preact = x @ w_up + b_up
    h = apply_act(act, preact, shift) * neuron_mask
    return h @ w_down, preact


def gated_ffn_ref(x, w_gate, w_up, w_down, neuron_mask, act: str, shift: float = 1.0):
    """Gated FFN (Llama SwiGLU style): down( mask * act(x@w_gate) * (x@w_up) ).

    The paper's relufication targets the *gate* activation: sparsity is
    determined by act(x @ w_gate) == 0, which zeroes the whole elementwise
    product regardless of the up-projection value.

    Returns (out [BT, d], preact [BT, F]) where preact = x @ w_gate.
    """
    preact = x @ w_gate
    h = apply_act(act, preact, shift) * neuron_mask * (x @ w_up)
    return h @ w_down, preact


def masked_matvec_ref(w, a, mask):
    """Row-structured sparse matvec (paper Fig 9a): y = (a * mask) @ w.

    w: [F, d], a: [F], mask: [F]. Rows of `w` whose mask/activation entry is
    zero contribute nothing — the rust substrate (rust/src/sparse) skips them
    outright; this oracle defines the semantics.
    """
    return (a * mask) @ w
