"""Activation zoo (paper §3.2, Fig 2a).

Every gate in the paper is an instance of f(x) = x * sigma(beta * x):
beta=1 -> SiLU, beta~=1.7 -> GELU approximation, beta -> inf -> ReLU.
`srelu` is the paper's shifted ReLU, ReLU(x - b) (§5.3), with `b` chosen
from the preactivation histogram.

These run at build time only (inside the JAX model that is AOT-lowered to
HLO); the rust cost model mirrors their *sparsity* semantics, never their
numerics.
"""

from __future__ import annotations

import jax.numpy as jnp

#: Activations whose output is exactly zero on a set of positive measure,
#: i.e. the ones that produce true activation sparsity.
SPARSE_ACTS = ("relu", "srelu")

#: All activation names understood by the model builder.
ACT_NAMES = ("relu", "gelu", "silu", "bsilu8", "srelu")


def beta_silu(x, beta):
    """The paper's unified gate f(x) = x * sigmoid(beta * x)."""
    return x * jnp.reciprocal(1.0 + jnp.exp(-beta * x))


def apply_act(name: str, x, shift: float = 1.0):
    """Apply activation `name` to preactivation `x`.

    `shift` only affects `srelu` (ReLU(x - shift)).
    """
    if name == "relu":
        return jnp.maximum(x, 0.0)
    if name == "srelu":
        return jnp.maximum(x - shift, 0.0)
    if name == "gelu":
        # tanh approximation, matches jax.nn.gelu(approximate=True)
        c = 0.7978845608028654  # sqrt(2/pi)
        return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))
    if name == "silu":
        return beta_silu(x, 1.0)
    if name == "bsilu8":
        return beta_silu(x, 8.0)
    raise ValueError(f"unknown activation: {name}")


def act_zero_mask(name: str, y):
    """Mask of *post*-activation values that are exactly zero.

    This is the quantity the paper calls activation sparsity: entries for
    which the corresponding down-projection row can be skipped entirely.
    For smooth gates (gelu/silu) the exact-zero set is negligible, which is
    precisely the paper's point.
    """
    del name
    return (y != 0.0).astype(jnp.float32)
